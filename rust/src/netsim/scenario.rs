//! Dynamic-network scenarios: time-varying perturbations of a [`DelayModel`].
//!
//! The paper computes throughput for a *static* delay model, but its own
//! premise — measurable, fluctuating WAN characteristics — implies that
//! delays drift, silos straggle, and links fail mid-training. A [`Scenario`]
//! describes such an operating condition as a composition of perturbations,
//! resolved by name exactly the way `Underlay::by_name` resolves underlays:
//!
//! | spec                        | meaning                                        |
//! |-----------------------------|------------------------------------------------|
//! | `scenario:identity`         | no perturbation (pins dynamic == static)       |
//! | `scenario:drift:0.3`        | per-silo access-bandwidth drift, log-OU walk   |
//! |                             | with per-round shock σ = 0.3, reversion 0.1    |
//! | `scenario:congestion:50:x4` | periodic core congestion: alternating 50-round |
//! |                             | blocks with core bandwidth ÷ 4                 |
//! | `scenario:straggler:3:x10`  | 3 straggler silos: computation × 10 **and**    |
//! |                             | access capacity ÷ 10 (a fully slowed silo)     |
//! | `scenario:churn:p0.01[:x3]` | link churn: each overlay arc fails per round   |
//! |                             | w.p. 0.01; a failed transfer retries, ×3 delay |
//! | `scenario:silo-churn:p0.05[:x3]` | silo churn: a down silo's round (compute  |
//! |                             | + all incident transfers) stretches ×3         |
//! | `scenario:outage:4:p0.05:x3` | correlated regional slowdowns: silos split    |
//! |                             | into 4 contiguous index regions; each region   |
//! |                             | independently sampled w.p. 0.05 per round, and |
//! |                             | a sampled region's silos share the **one**     |
//! |                             | draw — they all stretch ×3 together            |
//!
//! Composites join specs with `+` (`scenario:drift:0.3+churn:p0.01`). The
//! `scenario:` prefix is optional on input and canonical on output.
//!
//! Two deliberate modelling choices:
//!
//! * **Churn slows, never skips.** Removing an arc from a max-plus
//!   recurrence lets the receiver start *earlier* (it waits for fewer
//!   messages), which would make failures a speedup. A failed link instead
//!   multiplies that arc's round delay by a retry penalty — detection +
//!   retransmission after repair — so degradation is actually degrading.
//! * **Straggler identities are deterministic** — the evenly spaced silo
//!   indices `⌊t·N/count⌋` — so a scenario name alone fully determines the
//!   workload, with no hidden RNG state to replicate across runs.
//!
//! Per-round randomness (drift shocks, churn coin flips) comes from the
//! seeded [`Rng`], forked per perturbation; churn decisions are hashed per
//! `(round, arc)` so they are order-independent. [`RoundState::delay_digraph`]
//! materializes round k's Eq.-(3) digraph for any overlay; under the identity
//! scenario it is **bit-identical** to `DelayModel::delay_digraph` (every
//! multiplier is an exact `1.0 ×` no-op), which `tests/dynamic.rs` pins.

use super::delay::{DelayModel, OverlayDelayCsr};
use crate::graph::DiGraph;
use crate::maxplus::csr::{BatchedCsrWeights, CsrDelayDigraph};
use crate::maxplus::recurrence::{BatchedTimeline, Timeline};
use crate::maxplus::DelayDigraph;
use crate::util::rng::Rng;
use anyhow::Result;

/// Default retry stretch for churned links / silos (detect + retransmit).
pub const DEFAULT_CHURN_PENALTY: f64 = 3.0;

/// Mean-reversion rate of the drift log-walk (log-OU: `x ← (1−θ)x + σz`).
/// Keeps long-horizon bandwidth fluctuating instead of wandering to 0 / ∞;
/// the stationary std is `σ/√(2θ−θ²) ≈ 2.3σ`.
pub const DRIFT_REVERSION: f64 = 0.1;

/// One time-varying perturbation of the delay model.
#[derive(Clone, Debug, PartialEq)]
pub enum Perturbation {
    /// Per-silo access-bandwidth drift: seeded log-OU random walk with
    /// per-round shock std `sigma`.
    Drift { sigma: f64 },
    /// Periodic core congestion: alternating `period`-round blocks; during a
    /// congested block every routed bandwidth A(i',j') is divided by
    /// `factor`.
    Congestion { period: usize, factor: f64 },
    /// `count` straggler silos (evenly spaced indices): computation time
    /// × `factor`, access capacities ÷ `factor`.
    Straggler { count: usize, factor: f64 },
    /// Link churn: each overlay arc independently fails with probability `p`
    /// per round; the failed transfer's delay stretches by `penalty`
    /// (repair is implicit — next round the coin is re-flipped).
    LinkChurn { p: f64, penalty: f64 },
    /// Silo churn: each silo independently goes down with probability `p`
    /// per round; its compute and every incident transfer stretch by
    /// `penalty`.
    SiloChurn { p: f64, penalty: f64 },
    /// Correlated regional slowdowns (ROADMAP open item): silos are
    /// partitioned into `regions` contiguous index regions
    /// `[⌊r·n/R⌋, ⌊(r+1)·n/R⌋)`; each round every region is independently
    /// sampled with probability `p`, and a sampled region's silos all share
    /// that one draw — compute and incident transfers stretch by `factor`
    /// together (a regional datacenter/backbone event, not i.i.d. noise).
    Outage { regions: usize, p: f64, factor: f64 },
}

/// A named, reproducible dynamic-network scenario: a (possibly empty)
/// composition of [`Perturbation`]s.
#[derive(Clone, Debug)]
pub struct Scenario {
    name: String,
    perts: Vec<Perturbation>,
}

impl Scenario {
    /// The identity scenario: no perturbations, dynamic == static.
    pub fn identity() -> Scenario {
        Scenario {
            name: "scenario:identity".to_string(),
            perts: Vec::new(),
        }
    }

    /// Canonical name (`scenario:` prefix included).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The composed perturbations (empty for the identity).
    pub fn perturbations(&self) -> &[Perturbation] {
        &self.perts
    }

    /// True when this scenario leaves the delay model untouched.
    pub fn is_identity(&self) -> bool {
        self.perts.is_empty()
    }

    /// Resolve a scenario spec. Accepts the `scenario:` prefix or the bare
    /// spec, and `+`-joined composites. This is the single entry point the
    /// CLI, experiments, benches, and tests go through (the PR-1 convention
    /// for underlay names, extended to operating conditions) — a thin
    /// delegate into the [`crate::spec::Resolve`] registry, so errors echo
    /// the full input *and* name the failing segment of a composite.
    ///
    /// # Examples
    ///
    /// ```
    /// use fedtopo::netsim::scenario::Scenario;
    ///
    /// // the 'scenario:' prefix is optional; composites join with '+'
    /// let s = Scenario::by_name("straggler:3:x10+drift:0.3").unwrap();
    /// assert_eq!(s.name(), "scenario:straggler:3:x10+drift:0.3");
    /// assert_eq!(s.perturbations().len(), 2);
    ///
    /// // errors echo the full spec and name the failing segment
    /// let err = Scenario::by_name("drift:0.1+bogus:1").unwrap_err().to_string();
    /// assert!(err.starts_with("cannot resolve scenario 'drift:0.1+bogus:1'"));
    /// assert!(err.contains("(in segment 'bogus:1')"));
    /// ```
    pub fn by_name(name: &str) -> Result<Scenario> {
        <Scenario as crate::spec::Resolve>::resolve(name)
    }

    /// Representative builtin specs (benches / docs / smoke tests).
    pub fn builtin_names() -> &'static [&'static str] {
        &[
            "scenario:identity",
            "scenario:drift:0.3",
            "scenario:congestion:50:x4",
            "scenario:straggler:3:x10",
            "scenario:churn:p0.01",
            "scenario:silo-churn:p0.05",
            "scenario:outage:4:p0.05:x3",
        ]
    }

    /// Instantiate the scenario's stochastic process for `n` silos. The
    /// process is sequential: call [`ScenarioProcess::advance`] once per
    /// round, in order.
    pub fn process(&self, n: usize, seed: u64) -> ScenarioProcess {
        let mut root = Rng::new(seed ^ 0x5CE7_A110);
        let states = self
            .perts
            .iter()
            .enumerate()
            .map(|(idx, p)| PertState::new(p, n, root.fork(idx as u64)))
            .collect();
        ScenarioProcess {
            n,
            next_round: 0,
            states,
        }
    }
}

impl crate::spec::Resolve for Scenario {
    const KIND: &'static str = "scenario";

    /// Names are the perturbation *families* (suggestion candidates);
    /// most take arguments, see [`Resolve::grammar`].
    fn names() -> Vec<&'static str> {
        vec![
            "identity",
            "drift",
            "congestion",
            "straggler",
            "churn",
            "silo-churn",
            "outage",
        ]
    }

    fn aliases() -> Vec<&'static str> {
        vec!["none"]
    }

    fn grammar() -> String {
        "identity | drift:<sigma> | congestion:<period>:x<factor> | \
         straggler:<count>:x<factor> | churn:p<prob>[:x<penalty>] | \
         silo-churn:p<prob>[:x<penalty>] | outage:<regions>:p<prob>:x<factor>, \
         '+'-composable, optional 'scenario:' prefix"
            .to_string()
    }

    fn parse_spec(input: &str) -> Result<Scenario, crate::spec::ResolveError> {
        use crate::spec::{Resolve, ResolveError};
        let bare = input.strip_prefix("scenario:").unwrap_or(input);
        if bare.is_empty() {
            return Err(ResolveError::new(Self::KIND, input, "empty scenario spec")
                .expected(Self::grammar()));
        }
        let composite = bare.contains('+');
        let mut perts = Vec::new();
        for part in bare.split('+') {
            match parse_one(part) {
                Ok(Some(p)) => perts.push(p),
                Ok(None) => {}
                Err(e) => {
                    // Normalize: errors always echo the caller's full input;
                    // composites additionally name the failing segment.
                    return Err(if composite {
                        e.in_composite(input, part)
                    } else {
                        e.for_input(input)
                    });
                }
            }
        }
        Ok(Scenario {
            name: format!("scenario:{bare}"),
            perts,
        })
    }
}

/// Parse a single `family[:args]` spec; `identity`/`none` contribute
/// nothing. Errors carry the segment as their input; [`Scenario::by_name`]
/// re-homes them onto the full composite spec.
fn parse_one(spec: &str) -> Result<Option<Perturbation>, crate::spec::ResolveError> {
    use crate::spec::{Resolve, ResolveError};
    let err = |reason: String| {
        ResolveError::new(<Scenario as Resolve>::KIND, spec, reason)
            .expected(<Scenario as Resolve>::grammar())
    };
    let mut it = spec.split(':');
    let family = it.next().unwrap_or("");
    let args: Vec<&str> = it.collect();
    let wrong_arity = |want: &str| err(format!("expected {family}:{want}"));
    match family {
        "identity" | "none" => {
            if !args.is_empty() {
                return Err(err("identity takes no arguments".to_string()));
            }
            Ok(None)
        }
        "drift" => {
            let &[sigma] = &args[..] else {
                return Err(wrong_arity("<sigma>"));
            };
            let sigma = parse_pos(sigma, "sigma").map_err(err)?;
            Ok(Some(Perturbation::Drift { sigma }))
        }
        "congestion" => {
            let &[period, factor] = &args[..] else {
                return Err(wrong_arity("<period>:x<factor>"));
            };
            let period: usize = period
                .parse()
                .map_err(|_| err(format!("bad period '{period}'")))?;
            if period == 0 {
                return Err(err("period must be ≥ 1".to_string()));
            }
            let factor = parse_factor(factor).map_err(err)?;
            Ok(Some(Perturbation::Congestion { period, factor }))
        }
        "straggler" => {
            let &[count, factor] = &args[..] else {
                return Err(wrong_arity("<count>:x<factor>"));
            };
            let count: usize = count
                .parse()
                .map_err(|_| err(format!("bad count '{count}'")))?;
            if count == 0 {
                return Err(err("straggler count must be ≥ 1".to_string()));
            }
            let factor = parse_factor(factor).map_err(err)?;
            Ok(Some(Perturbation::Straggler { count, factor }))
        }
        "churn" | "silo-churn" => {
            let (p, penalty) = match &args[..] {
                &[p] => (parse_prob(p).map_err(err)?, DEFAULT_CHURN_PENALTY),
                &[p, pen] => (parse_prob(p).map_err(err)?, parse_factor(pen).map_err(err)?),
                _ => return Err(wrong_arity("p<prob>[:x<penalty>]")),
            };
            Ok(Some(if family == "churn" {
                Perturbation::LinkChurn { p, penalty }
            } else {
                Perturbation::SiloChurn { p, penalty }
            }))
        }
        "outage" => {
            let &[regions, p, factor] = &args[..] else {
                return Err(wrong_arity("<region-count>:p<prob>:x<factor>"));
            };
            let regions: usize = regions
                .parse()
                .map_err(|_| err(format!("bad region count '{regions}'")))?;
            if regions == 0 {
                return Err(err("region count must be ≥ 1".to_string()));
            }
            let p = parse_prob(p).map_err(err)?;
            let factor = parse_factor(factor).map_err(err)?;
            Ok(Some(Perturbation::Outage { regions, p, factor }))
        }
        other => Err(err(format!("unknown scenario family '{other}'"))
            .suggest(other, &<Scenario as Resolve>::names())),
    }
}

fn parse_pos(s: &str, what: &str) -> Result<f64, String> {
    let v: f64 = s.parse().map_err(|_| format!("bad {what} '{s}'"))?;
    if v <= 0.0 || !v.is_finite() {
        return Err(format!("{what} must be a positive finite number"));
    }
    Ok(v)
}

/// `x10` or plain `10`; must be ≥ 1 (a slowdown).
fn parse_factor(s: &str) -> Result<f64, String> {
    let v = parse_pos(s.strip_prefix('x').unwrap_or(s), "factor")?;
    if v < 1.0 {
        return Err(format!("factor 'x{v}' must be ≥ 1"));
    }
    Ok(v)
}

/// `p0.01` or plain `0.01`; must lie in [0, 1].
fn parse_prob(s: &str) -> Result<f64, String> {
    let raw = s.strip_prefix('p').unwrap_or(s);
    let v: f64 = raw.parse().map_err(|_| format!("bad probability '{s}'"))?;
    if !(0.0..=1.0).contains(&v) {
        return Err(format!("probability {v} outside [0, 1]"));
    }
    Ok(v)
}

/// Evenly spaced straggler identities `⌊t·n/count⌋` (deterministic).
pub fn straggler_silos(n: usize, count: usize) -> Vec<usize> {
    let count = count.min(n);
    (0..count).map(|t| t * n / count).collect()
}

/// Per-perturbation evolving state inside a [`ScenarioProcess`].
#[derive(Clone, Debug)]
enum PertState {
    Drift { sigma: f64, x: Vec<f64>, rng: Rng },
    Congestion { period: usize, factor: f64 },
    Straggler { silos: Vec<usize>, factor: f64 },
    LinkChurn { p: f64, penalty: f64, rng: Rng },
    SiloChurn { p: f64, penalty: f64, rng: Rng },
    Outage {
        /// Region boundaries: region r spans `starts[r]..starts[r + 1]`.
        starts: Vec<usize>,
        p: f64,
        factor: f64,
        rng: Rng,
    },
}

impl PertState {
    fn new(p: &Perturbation, n: usize, rng: Rng) -> PertState {
        match *p {
            Perturbation::Drift { sigma } => PertState::Drift {
                sigma,
                x: vec![0.0; n],
                rng,
            },
            Perturbation::Congestion { period, factor } => {
                PertState::Congestion { period, factor }
            }
            Perturbation::Straggler { count, factor } => PertState::Straggler {
                silos: straggler_silos(n, count),
                factor,
            },
            Perturbation::LinkChurn { p, penalty } => PertState::LinkChurn { p, penalty, rng },
            Perturbation::SiloChurn { p, penalty } => PertState::SiloChurn { p, penalty, rng },
            Perturbation::Outage { regions, p, factor } => PertState::Outage {
                starts: (0..=regions).map(|r| r * n / regions).collect(),
                p,
                factor,
                rng,
            },
        }
    }

    fn apply(&mut self, k: usize, st: &mut RoundState) {
        match self {
            PertState::Drift { sigma, x, rng } => {
                for (i, xi) in x.iter_mut().enumerate() {
                    *xi = (1.0 - DRIFT_REVERSION) * *xi + *sigma * rng.normal();
                    st.access_mult[i] *= xi.exp();
                }
            }
            PertState::Congestion { period, factor } => {
                if (k / *period) % 2 == 1 {
                    st.core_mult /= *factor;
                }
            }
            PertState::Straggler { silos, factor } => {
                for &i in silos.iter() {
                    st.compute_mult[i] *= *factor;
                    st.access_mult[i] /= *factor;
                }
            }
            PertState::LinkChurn { p, penalty, rng } => {
                st.link_churn.push((*p, *penalty, rng.next_u64()));
            }
            PertState::SiloChurn { p, penalty, rng } => {
                // Only silo_penalty: arcs pick it up via arc_penalty and the
                // self-loop via delay_digraph, each exactly once. Writing it
                // into compute_mult too would square the stretch on outgoing
                // arcs and leak memoryless churn into perturbed_model.
                for i in 0..st.silo_penalty.len() {
                    if rng.bool(*p) {
                        st.silo_penalty[i] *= *penalty;
                    }
                }
            }
            PertState::Outage { starts, p, factor, rng } => {
                // One draw per region per round — every silo of a sampled
                // region stretches together (same silo_penalty channel as
                // silo-churn: memoryless, stays out of the measured model).
                for r in 0..starts.len() - 1 {
                    if rng.bool(*p) {
                        for i in starts[r]..starts[r + 1] {
                            st.silo_penalty[i] *= *factor;
                        }
                    }
                }
            }
        }
    }
}

/// The sequential realization of a scenario: one [`RoundState`] per round.
#[derive(Clone, Debug)]
pub struct ScenarioProcess {
    n: usize,
    next_round: usize,
    states: Vec<PertState>,
}

impl ScenarioProcess {
    /// Produce the next round's network state. Strictly sequential — the
    /// drift walk and churn streams evolve per call.
    pub fn advance(&mut self) -> RoundState {
        let mut st = RoundState::unperturbed(self.n, 0);
        self.advance_into(&mut st);
        st
    }

    /// [`ScenarioProcess::advance`] into a caller-owned, reused
    /// [`RoundState`]: resets the multipliers in place (no allocation —
    /// `link_churn` keeps its capacity) and applies the perturbations.
    /// Bit-identical to `advance()` fed the same stream position; the
    /// zero-allocation per-round loops (`simulate_scenario`,
    /// `topology::adaptive`, `fl::trainsim`) drive this form.
    pub fn advance_into(&mut self, st: &mut RoundState) {
        let k = self.next_round;
        self.next_round += 1;
        st.reset(self.n, k);
        for ps in &mut self.states {
            ps.apply(k, st);
        }
    }
}

/// The resolved perturbation of one round: multipliers on top of a base
/// [`DelayModel`]. All-ones (the identity scenario) reproduces the base
/// model's delays bit-for-bit.
#[derive(Clone, Debug)]
pub struct RoundState {
    pub round: usize,
    /// Per-silo multiplier on the computation phase `s·T_c(i)` (≥ 1 slows).
    pub compute_mult: Vec<f64>,
    /// Per-silo multiplier on access capacities C_UP / C_DN (< 1 slows).
    pub access_mult: Vec<f64>,
    /// Multiplier on every routed core bandwidth A(i',j') (< 1 slows).
    pub core_mult: f64,
    /// Per-silo churn stretch (1 = up; > 1 = down this round, transfers and
    /// compute stretched).
    pub silo_penalty: Vec<f64>,
    /// Link-churn layers: `(p, penalty, salt)`; arcs are resolved via
    /// [`RoundState::arc_penalty`] with a per-(round, arc) hash.
    link_churn: Vec<(f64, f64, u64)>,
}

impl RoundState {
    /// The all-ones state (reproduces the base model bit-for-bit). Public
    /// so per-round loops can own one reusable instance for
    /// [`ScenarioProcess::advance_into`].
    pub fn unperturbed(n: usize, round: usize) -> RoundState {
        RoundState {
            round,
            compute_mult: vec![1.0; n],
            access_mult: vec![1.0; n],
            core_mult: 1.0,
            silo_penalty: vec![1.0; n],
            link_churn: Vec::new(),
        }
    }

    /// Reset to the all-ones state in place (buffers keep their capacity).
    fn reset(&mut self, n: usize, round: usize) {
        assert_eq!(self.compute_mult.len(), n, "round state resized");
        self.round = round;
        self.compute_mult.fill(1.0);
        self.access_mult.fill(1.0);
        self.core_mult = 1.0;
        self.silo_penalty.fill(1.0);
        self.link_churn.clear();
    }

    /// Retry stretch of arc (i → j) this round: 1.0 when healthy, the
    /// product of the triggered churn penalties otherwise. Order-independent
    /// (each decision hashes the round salt with the arc endpoints).
    pub fn arc_penalty(&self, i: usize, j: usize) -> f64 {
        let mut m = self.silo_penalty[i] * self.silo_penalty[j];
        for &(p, penalty, salt) in &self.link_churn {
            let h = salt
                ^ (((i as u64) << 32) | (j as u64)).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            if Rng::new(h).f64() < p {
                m *= penalty;
            }
        }
        m
    }

    /// Materialize round `round`'s Eq.-(3) delay digraph for `overlay` under
    /// this state: perturbed self-loops plus perturbed, churn-stretched arc
    /// delays. Identity state ⇒ bit-identical to
    /// [`DelayModel::delay_digraph`].
    pub fn delay_digraph(&self, dm: &DelayModel, overlay: &DiGraph) -> DelayDigraph {
        assert_eq!(overlay.n(), dm.n);
        assert_eq!(self.compute_mult.len(), dm.n);
        let mut g = DelayDigraph::new(dm.n);
        for i in 0..dm.n {
            // A down silo's computation phase stretches too (silo_penalty);
            // 1.0 × keeps the identity case bit-exact.
            g.arc(
                i,
                i,
                self.silo_penalty[i] * (self.compute_mult[i] * dm.compute_ms(i)),
            );
        }
        for (i, j, _) in overlay.edges() {
            let out_deg = overlay.out_degree(i).max(1);
            let in_deg = overlay.in_degree(j).max(1);
            let d = dm.d_o_perturbed(
                i,
                j,
                out_deg,
                in_deg,
                self.compute_mult[i],
                self.access_mult[i],
                self.access_mult[j],
                self.core_mult,
            );
            g.arc(i, j, self.arc_penalty(i, j) * d);
        }
        g
    }

    /// Rewrite a designed overlay's CSR delay weights in place for this
    /// round — the zero-allocation counterpart of
    /// [`RoundState::delay_digraph`]. Every weight is computed by the exact
    /// same float expressions (`d_o_perturbed`, `arc_penalty`, the
    /// self-loop product), so the stepped trajectories are bit-identical to
    /// the dense path's; only the storage differs. The structure (arc set,
    /// degrees) is never touched — that is a re-design, not a round.
    pub fn reweight(&self, dm: &DelayModel, ov: &mut OverlayDelayCsr) {
        let OverlayDelayCsr { csr, out_deg, in_deg } = ov;
        self.reweight_parts(dm, out_deg, in_deg, csr);
    }

    /// [`RoundState::reweight`] over pre-split parts (callers that hand the
    /// CSR to [`Timeline::simulate_reweighted`] while holding the degree
    /// slices themselves).
    pub fn reweight_parts(
        &self,
        dm: &DelayModel,
        out_deg: &[u32],
        in_deg: &[u32],
        csr: &mut CsrDelayDigraph,
    ) {
        assert_eq!(csr.n(), dm.n);
        assert_eq!(self.compute_mult.len(), dm.n);
        csr.for_each_arc_mut(|dst, src, w| {
            *w = self.arc_weight(dm, out_deg, in_deg, dst, src);
        });
    }

    /// The perturbed weight of one CSR arc `(src → dst)` under this state —
    /// the shared float-expression core of every reweight path. Both the
    /// per-cell [`RoundState::reweight_parts`] and the batched
    /// [`BatchedRoundState::reweight`] write weights by calling *this
    /// function*, so their bit-equality is a function-extraction identity,
    /// not a maintained invariant.
    #[inline]
    pub fn arc_weight(
        &self,
        dm: &DelayModel,
        out_deg: &[u32],
        in_deg: &[u32],
        dst: usize,
        src: usize,
    ) -> f64 {
        if dst == src {
            // A down silo's computation phase stretches too
            // (silo_penalty); 1.0 × keeps the identity case bit-exact.
            self.silo_penalty[dst] * (self.compute_mult[dst] * dm.compute_ms(dst))
        } else {
            let d = dm.d_o_perturbed(
                src,
                dst,
                (out_deg[src] as usize).max(1),
                (in_deg[dst] as usize).max(1),
                self.compute_mult[src],
                self.access_mult[src],
                self.access_mult[dst],
                self.core_mult,
            );
            self.arc_penalty(src, dst) * d
        }
    }

    /// The network an adaptive designer would *measure* this round: the base
    /// model with computation times, access capacities, and routed core
    /// bandwidths rescaled by the current multipliers. Churn is memoryless,
    /// so it does not enter the measured model. O(n²) — called on re-design
    /// events, not per round.
    pub fn perturbed_model(&self, dm: &DelayModel) -> DelayModel {
        let mut m = dm.clone();
        for i in 0..dm.n {
            m.tc_ms[i] *= self.compute_mult[i];
            m.cup_bps[i] *= self.access_mult[i];
            m.cdn_bps[i] *= self.access_mult[i];
        }
        if self.core_mult != 1.0 {
            m.routes.scale_abw(self.core_mult);
        }
        m
    }
}

/// Wall-clock reconstruction of `rounds` rounds of `overlay` under a
/// scenario: the Algorithm-3 recurrence with the delay digraph re-weighted
/// per round. Under [`Scenario::identity`] this equals
/// `Timeline::simulate(&dm.delay_digraph(overlay), rounds)` bit-for-bit.
///
/// Flat path (PR 5): one reusable CSR digraph + one reusable
/// [`RoundState`]; after setup the per-round loop does **zero** heap
/// allocation. Bit-identical to [`simulate_scenario_dense`], the retained
/// dense oracle (pinned in tests and `tests/csr_equiv.rs`).
pub fn simulate_scenario(
    dm: &DelayModel,
    overlay: &DiGraph,
    scenario: &Scenario,
    rounds: usize,
    seed: u64,
) -> Timeline {
    let mut proc = scenario.process(dm.n, seed);
    let OverlayDelayCsr { mut csr, out_deg, in_deg } = dm.delay_csr(overlay);
    let mut st = RoundState::unperturbed(dm.n, 0);
    Timeline::simulate_reweighted(&mut csr, rounds, |_k, g: &mut CsrDelayDigraph| {
        proc.advance_into(&mut st);
        st.reweight_parts(dm, &out_deg, &in_deg, g);
    })
}

/// `S` independent scenario realizations advanced in lockstep over one
/// shared overlay structure — the reweight half of the PR-6 batched SoA
/// stepping path.
///
/// Lane `l` owns its own [`ScenarioProcess`] (its own seed, its own drift
/// walk and churn streams) and its own reusable [`RoundState`];
/// [`BatchedRoundState::reweight`] writes lane `l` of every arc with
/// [`RoundState::arc_weight`] — literally the same function the per-cell
/// [`RoundState::reweight_parts`] calls — so each lane's weight stream is
/// bit-identical to running that `(scenario, seed)` cell alone.
#[derive(Clone, Debug)]
pub struct BatchedRoundState {
    procs: Vec<ScenarioProcess>,
    states: Vec<RoundState>,
}

impl BatchedRoundState {
    /// One lane per `(scenario, seed)` pair, for `n` silos.
    pub fn new(n: usize, lanes: &[(Scenario, u64)]) -> BatchedRoundState {
        assert!(!lanes.is_empty(), "need at least one lane");
        BatchedRoundState {
            procs: lanes.iter().map(|(sc, seed)| sc.process(n, *seed)).collect(),
            states: lanes.iter().map(|_| RoundState::unperturbed(n, 0)).collect(),
        }
    }

    pub fn lanes(&self) -> usize {
        self.states.len()
    }

    /// Lane `l`'s current round state (after [`BatchedRoundState::advance`]).
    pub fn lane_state(&self, l: usize) -> &RoundState {
        &self.states[l]
    }

    /// Advance every lane's scenario process one round, in place
    /// (zero-allocation; each lane is exactly one
    /// [`ScenarioProcess::advance_into`] call).
    pub fn advance(&mut self) {
        for (proc, st) in self.procs.iter_mut().zip(&mut self.states) {
            proc.advance_into(st);
        }
    }

    /// Write every lane of every arc for the current round: lane `l` of arc
    /// `(src → dst)` gets `states[l].arc_weight(..)` — the per-cell float
    /// expressions, per lane, in the per-cell arc order.
    pub fn reweight(
        &self,
        dm: &DelayModel,
        out_deg: &[u32],
        in_deg: &[u32],
        g: &CsrDelayDigraph,
        w: &mut BatchedCsrWeights,
    ) {
        assert_eq!(w.lanes(), self.states.len(), "lane count mismatch");
        assert_eq!(g.n(), dm.n);
        let states = &self.states;
        w.for_each_arc_lanes_mut(g, |dst, src, lanes_w| {
            for (wl, st) in lanes_w.iter_mut().zip(states) {
                *wl = st.arc_weight(dm, out_deg, in_deg, dst, src);
            }
        });
    }
}

/// Batched counterpart of [`simulate_scenario`]: run `lanes.len()`
/// `(scenario, seed)` cells of the *same* static overlay in one SoA pass
/// per round ([`crate::maxplus::recurrence::step_csr_batched_into`]).
/// Returns one [`Timeline`] per lane, bit-identical to
/// `simulate_scenario(dm, overlay, &lanes[l].0, rounds, lanes[l].1)`
/// (pinned in `tests/csr_equiv.rs`).
pub fn simulate_scenario_batched(
    dm: &DelayModel,
    overlay: &DiGraph,
    lanes: &[(Scenario, u64)],
    rounds: usize,
) -> Vec<Timeline> {
    let OverlayDelayCsr { csr, out_deg, in_deg } = dm.delay_csr(overlay);
    let mut brs = BatchedRoundState::new(dm.n, lanes);
    let mut w = BatchedCsrWeights::broadcast(&csr, lanes.len());
    let bt = BatchedTimeline::simulate_reweighted(&csr, &mut w, rounds, |_k, w| {
        brs.advance();
        brs.reweight(dm, &out_deg, &in_deg, &csr, w);
    });
    (0..lanes.len()).map(|l| bt.lane_timeline(l)).collect()
}

/// The pre-PR-5 per-round path — materialize a fresh [`DelayDigraph`] (and
/// its nested in-adjacency) every round — kept as the migration's
/// equivalence oracle. Do not grow features onto this; it exists so the
/// flat path above has something to be pinned bit-identical against.
pub fn simulate_scenario_dense(
    dm: &DelayModel,
    overlay: &DiGraph,
    scenario: &Scenario,
    rounds: usize,
    seed: u64,
) -> Timeline {
    let mut proc = scenario.process(dm.n, seed);
    Timeline::simulate_dynamic(dm.n, rounds, |_k| {
        proc.advance().delay_digraph(dm, overlay)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::workloads::Workload;
    use crate::netsim::underlay::Underlay;

    fn gaia_model() -> DelayModel {
        let net = Underlay::builtin("gaia").unwrap();
        DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9)
    }

    fn gaia_ring() -> DiGraph {
        let n = 11;
        let mut g = DiGraph::new(n);
        for i in 0..n {
            g.add_edge(i, (i + 1) % n, 0.0);
        }
        g
    }

    #[test]
    fn names_resolve_and_roundtrip() {
        for name in Scenario::builtin_names() {
            let sc = Scenario::by_name(name).unwrap();
            assert_eq!(sc.name(), *name);
            // prefix is optional on input
            let bare = name.strip_prefix("scenario:").unwrap();
            assert_eq!(Scenario::by_name(bare).unwrap().name(), *name);
        }
        assert!(Scenario::by_name("scenario:identity").unwrap().is_identity());
        assert!(!Scenario::by_name("drift:0.3").unwrap().is_identity());
    }

    #[test]
    fn composite_specs_parse() {
        let sc = Scenario::by_name("scenario:drift:0.3+churn:p0.01:x5").unwrap();
        assert_eq!(sc.perturbations().len(), 2);
        assert_eq!(
            sc.perturbations()[1],
            Perturbation::LinkChurn {
                p: 0.01,
                penalty: 5.0
            }
        );
    }

    #[test]
    fn bad_specs_rejected() {
        for bad in [
            "scenario:",
            "scenario:meteor",
            "scenario:drift",
            "scenario:drift:-1",
            "scenario:straggler:3",
            "scenario:straggler:three:x10",
            "scenario:churn:p1.5",
            "scenario:congestion:0:x4",
            "scenario:straggler:0:x10",
            "scenario:straggler:3:x0.5",
            "scenario:identity:7",
        ] {
            assert!(Scenario::by_name(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn straggler_silos_deterministic_and_spread() {
        assert_eq!(straggler_silos(11, 3), vec![0, 3, 7]);
        assert_eq!(straggler_silos(10, 5), vec![0, 2, 4, 6, 8]);
        assert_eq!(straggler_silos(4, 9), vec![0, 1, 2, 3]); // clamped
        let s = straggler_silos(200, 7);
        let mut d = s.clone();
        d.dedup();
        assert_eq!(d.len(), 7, "distinct identities");
    }

    #[test]
    fn identity_round_state_reproduces_delay_digraph_bitwise() {
        let dm = gaia_model();
        let ring = gaia_ring();
        let mut proc = Scenario::identity().process(dm.n, 7);
        let st = proc.advance();
        let a = dm.delay_digraph(&ring);
        let b = st.delay_digraph(&dm, &ring);
        assert_eq!(a.arcs.len(), b.arcs.len());
        for (x, y) in a.arcs.iter().zip(&b.arcs) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1, y.1);
            assert_eq!(x.2.to_bits(), y.2.to_bits());
        }
    }

    #[test]
    fn straggler_state_slows_the_right_silos() {
        let dm = gaia_model();
        let sc = Scenario::by_name("scenario:straggler:3:x10").unwrap();
        let mut proc = sc.process(dm.n, 7);
        let st = proc.advance();
        for i in 0..dm.n {
            if [0, 3, 7].contains(&i) {
                assert_eq!(st.compute_mult[i], 10.0);
                assert_eq!(st.access_mult[i], 0.1);
            } else {
                assert_eq!(st.compute_mult[i], 1.0);
                assert_eq!(st.access_mult[i], 1.0);
            }
        }
        let pm = st.perturbed_model(&dm);
        assert!((pm.tc_ms[0] - 254.0).abs() < 1e-9);
        assert!((pm.cup_bps[3] - 1e9).abs() < 1.0);
        assert!((pm.tc_ms[1] - 25.4).abs() < 1e-9);
    }

    #[test]
    fn drift_is_seeded_and_reproducible() {
        let sc = Scenario::by_name("scenario:drift:0.3").unwrap();
        let (mut a, mut b) = (sc.process(11, 42), sc.process(11, 42));
        let mut c = sc.process(11, 43);
        let mut diverged = false;
        for _ in 0..20 {
            let (sa, sb, sc_) = (a.advance(), b.advance(), c.advance());
            for i in 0..11 {
                assert_eq!(sa.access_mult[i].to_bits(), sb.access_mult[i].to_bits());
                assert!(sa.access_mult[i] > 0.0 && sa.access_mult[i].is_finite());
                if sa.access_mult[i] != sc_.access_mult[i] {
                    diverged = true;
                }
            }
        }
        assert!(diverged, "different seeds must give different drift paths");
    }

    #[test]
    fn congestion_alternates_blocks() {
        let sc = Scenario::by_name("scenario:congestion:5:x4").unwrap();
        let mut proc = sc.process(4, 7);
        let mut mults = Vec::new();
        for _ in 0..20 {
            mults.push(proc.advance().core_mult);
        }
        for k in 0..20 {
            let expect = if (k / 5) % 2 == 1 { 0.25 } else { 1.0 };
            assert_eq!(mults[k], expect, "round {k}");
        }
    }

    #[test]
    fn churn_penalizes_never_removes() {
        let dm = gaia_model();
        let ring = gaia_ring();
        let sc = Scenario::by_name("scenario:churn:p0.5:x3").unwrap();
        let mut proc = sc.process(dm.n, 7);
        let base = dm.delay_digraph(&ring);
        let mut hit = 0;
        for _ in 0..30 {
            let g = proc.advance().delay_digraph(&dm, &ring);
            // same arc set, delays only ever stretched
            assert_eq!(g.arcs.len(), base.arcs.len());
            for (p, b) in g.arcs.iter().zip(&base.arcs) {
                assert_eq!((p.0, p.1), (b.0, b.1));
                assert!(p.2 >= b.2 - 1e-12, "churn must not speed arcs up");
                if p.2 > b.2 * 1.5 {
                    hit += 1;
                }
            }
        }
        // p = 0.5 over 30 rounds × 11 arcs: some retries must have fired
        assert!(hit > 50, "only {hit} churn hits at p=0.5");
    }

    #[test]
    fn silo_churn_stretches_compute_and_arcs_exactly_once() {
        let dm = gaia_model();
        let ring = gaia_ring();
        let sc = Scenario::by_name("scenario:silo-churn:p1.0:x2").unwrap();
        let mut proc = sc.process(dm.n, 7);
        let st = proc.advance();
        for i in 0..dm.n {
            // churn must stay out of the measured-model multipliers
            assert_eq!(st.compute_mult[i], 1.0);
            assert_eq!(st.silo_penalty[i], 2.0);
        }
        // both endpoints down: arc pays both penalties
        assert_eq!(st.arc_penalty(0, 1), 4.0);
        // self-loop ×2, arc delay ×4 (both endpoints) — not ×8
        let base = dm.delay_digraph(&ring);
        let g = st.delay_digraph(&dm, &ring);
        assert_eq!(g.arcs[0].2, 2.0 * base.arcs[0].2, "self-loop stretch");
        let (_, _, d0) = base.arcs[dm.n]; // first ring arc after the self-loops
        let (_, _, d1) = g.arcs[dm.n];
        assert_eq!(d1, 4.0 * d0, "arc stretch must be penalty², not penalty³");
        // and the designer-facing measured model is untouched by churn
        let pm = st.perturbed_model(&dm);
        assert_eq!(pm.tc_ms, dm.tc_ms);
        assert_eq!(pm.cup_bps, dm.cup_bps);
    }

    #[test]
    fn scenario_timeline_monotone_under_every_builtin() {
        let dm = gaia_model();
        let ring = gaia_ring();
        for name in Scenario::builtin_names() {
            let sc = Scenario::by_name(name).unwrap();
            let tl = simulate_scenario(&dm, &ring, &sc, 60, 7);
            assert_eq!(tl.rounds(), 60);
            for k in 0..60 {
                for i in 0..dm.n {
                    assert!(
                        tl.at(k + 1, i) >= tl.at(k, i),
                        "{name}: t not monotone at k={k} i={i}"
                    );
                }
            }
            assert!(tl.round_completion(60).is_finite());
        }
    }

    #[test]
    fn flat_simulate_matches_dense_oracle_bitwise() {
        let dm = gaia_model();
        let ring = gaia_ring();
        for spec in [
            "scenario:identity",
            "scenario:drift:0.3+churn:p0.05",
            "scenario:straggler:3:x10+silo-churn:p0.1",
            "scenario:outage:3:p0.2:x4+congestion:10:x2",
        ] {
            let sc = Scenario::by_name(spec).unwrap();
            let flat = simulate_scenario(&dm, &ring, &sc, 80, 7);
            let dense = simulate_scenario_dense(&dm, &ring, &sc, 80, 7);
            assert_eq!(flat.rounds(), dense.rounds());
            for k in 0..=80 {
                for i in 0..dm.n {
                    assert_eq!(
                        flat.at(k, i).to_bits(),
                        dense.at(k, i).to_bits(),
                        "{spec}: t[{k}][{i}]"
                    );
                }
            }
        }
    }

    #[test]
    fn advance_into_matches_advance_bitwise() {
        let sc = Scenario::by_name("scenario:drift:0.3+outage:3:p0.3:x2+churn:p0.2").unwrap();
        let mut a = sc.process(11, 42);
        let mut b = sc.process(11, 42);
        let mut st = RoundState::unperturbed(11, 0);
        for k in 0..25 {
            let fresh = a.advance();
            b.advance_into(&mut st);
            assert_eq!(st.round, k);
            assert_eq!(fresh.round, k);
            for i in 0..11 {
                assert_eq!(fresh.compute_mult[i].to_bits(), st.compute_mult[i].to_bits());
                assert_eq!(fresh.access_mult[i].to_bits(), st.access_mult[i].to_bits());
                assert_eq!(fresh.silo_penalty[i].to_bits(), st.silo_penalty[i].to_bits());
            }
            assert_eq!(fresh.core_mult.to_bits(), st.core_mult.to_bits());
            for (i, j) in [(0, 1), (5, 9)] {
                assert_eq!(fresh.arc_penalty(i, j).to_bits(), st.arc_penalty(i, j).to_bits());
            }
        }
    }

    #[test]
    fn reweight_matches_delay_digraph_weights_bitwise() {
        let dm = gaia_model();
        let ring = gaia_ring();
        let sc = Scenario::by_name("scenario:straggler:3:x10+drift:0.2+outage:2:p0.5:x3")
            .unwrap();
        let mut proc = sc.process(dm.n, 9);
        let mut ov = dm.delay_csr(&ring);
        for _ in 0..10 {
            let st = proc.advance();
            st.reweight(&dm, &mut ov);
            let dense = st.delay_digraph(&dm, &ring);
            let norm = |arcs: &[(usize, usize, f64)]| {
                let mut v: Vec<(usize, usize, u64)> =
                    arcs.iter().map(|&(s, d, w)| (s, d, w.to_bits())).collect();
                v.sort_unstable();
                v
            };
            assert_eq!(norm(&ov.csr.to_delay_digraph().arcs), norm(&dense.arcs));
        }
    }

    #[test]
    fn batched_reweight_lanes_match_per_cell_reweight_bitwise() {
        // Each lane of BatchedRoundState::reweight must equal reweight_parts
        // run for that (scenario, seed) alone — same rounds, same arc order.
        let dm = gaia_model();
        let ring = gaia_ring();
        let lanes: Vec<(Scenario, u64)> = [
            ("scenario:identity", 7),
            ("scenario:drift:0.3+churn:p0.05", 7),
            ("scenario:straggler:3:x10+silo-churn:p0.1", 11),
            ("scenario:outage:3:p0.2:x4+congestion:10:x2", 13),
        ]
        .iter()
        .map(|&(s, seed)| (Scenario::by_name(s).unwrap(), seed))
        .collect();
        let OverlayDelayCsr { csr, out_deg, in_deg } = dm.delay_csr(&ring);
        let mut brs = BatchedRoundState::new(dm.n, &lanes);
        let mut bw = BatchedCsrWeights::broadcast(&csr, lanes.len());
        // per-cell references: one process + CSR per lane
        let mut ref_procs: Vec<ScenarioProcess> =
            lanes.iter().map(|(sc, seed)| sc.process(dm.n, *seed)).collect();
        let mut ref_csrs: Vec<CsrDelayDigraph> = lanes.iter().map(|_| csr.clone()).collect();
        let mut ref_st = RoundState::unperturbed(dm.n, 0);
        for round in 0..15 {
            brs.advance();
            brs.reweight(&dm, &out_deg, &in_deg, &csr, &mut bw);
            for (l, (proc, lane_csr)) in
                ref_procs.iter_mut().zip(&mut ref_csrs).enumerate()
            {
                proc.advance_into(&mut ref_st);
                ref_st.reweight_parts(&dm, &out_deg, &in_deg, lane_csr);
                assert_eq!(brs.lane_state(l).round, round);
                let mut k = 0usize;
                lane_csr.for_each_arc_mut(|_, _, w| {
                    assert_eq!(
                        bw.arc_lanes(k)[l].to_bits(),
                        w.to_bits(),
                        "round {round} lane {l} arc {k}"
                    );
                    k += 1;
                });
            }
        }
    }

    #[test]
    fn simulate_scenario_batched_matches_per_cell_simulate() {
        let dm = gaia_model();
        let ring = gaia_ring();
        let lanes: Vec<(Scenario, u64)> = [
            ("scenario:straggler:3:x10", 7u64),
            ("scenario:drift:0.3", 9),
            ("scenario:identity", 7),
        ]
        .iter()
        .map(|&(s, seed)| (Scenario::by_name(s).unwrap(), seed))
        .collect();
        let tls = simulate_scenario_batched(&dm, &ring, &lanes, 40);
        assert_eq!(tls.len(), lanes.len());
        for (l, (sc, seed)) in lanes.iter().enumerate() {
            let reference = simulate_scenario(&dm, &ring, sc, 40, *seed);
            for k in 0..=40 {
                for i in 0..dm.n {
                    assert_eq!(
                        tls[l].at(k, i).to_bits(),
                        reference.at(k, i).to_bits(),
                        "lane {l} ({}) t[{k}][{i}]",
                        sc.name()
                    );
                }
            }
        }
    }

    #[test]
    fn outage_regions_slow_down_together() {
        // p = 1: every region sampled every round → every silo stretches by
        // exactly ×factor (one draw per region, shared by its silos).
        let sc = Scenario::by_name("scenario:outage:3:p1.0:x5").unwrap();
        let mut proc = sc.process(10, 7);
        let st = proc.advance();
        for i in 0..10 {
            assert_eq!(st.silo_penalty[i], 5.0, "silo {i}");
            // memoryless: stays out of the measured-model multipliers
            assert_eq!(st.compute_mult[i], 1.0);
        }
        // and the measured model is untouched (outage is not
        // topology-addressable by re-design)
        let dm = gaia_model();
        let sc2 = Scenario::by_name("scenario:outage:2:p1.0:x5").unwrap();
        let mut proc2 = sc2.process(dm.n, 7);
        let st2 = proc2.advance();
        let pm = st2.perturbed_model(&dm);
        assert_eq!(pm.tc_ms, dm.tc_ms);

        // correlation: with 2 regions over 10 silos, silos 0..5 share one
        // coin and 5..10 the other — within a region penalties are always
        // equal, across regions they must differ in some round at p = 0.5.
        let sc3 = Scenario::by_name("scenario:outage:2:p0.5:x2").unwrap();
        let mut proc3 = sc3.process(10, 11);
        let mut cross_diff = false;
        for _ in 0..40 {
            let st = proc3.advance();
            for r in [0usize, 1] {
                let base = st.silo_penalty[r * 5];
                for i in r * 5..(r + 1) * 5 {
                    assert_eq!(st.silo_penalty[i], base, "region {r} not correlated");
                }
            }
            if st.silo_penalty[0] != st.silo_penalty[5] {
                cross_diff = true;
            }
        }
        assert!(cross_diff, "regions must be sampled independently");
    }

    #[test]
    fn outage_bad_specs_rejected() {
        for bad in [
            "scenario:outage",
            "scenario:outage:0:p0.1:x2",
            "scenario:outage:3:p1.5:x2",
            "scenario:outage:3:p0.1:x0.5",
            "scenario:outage:3:p0.1",
        ] {
            assert!(Scenario::by_name(bad).is_err(), "{bad} should fail");
        }
    }
}
