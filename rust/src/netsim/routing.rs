//! Shortest-path routing + per-pair end-to-end latency and available
//! bandwidth — the measurable quantities MCT takes as input (Sect. 2.2).
//!
//! Routes follow latency-shortest paths over the core (the paper assumes
//! "shortest path routing with the geographical distance (or equivalently
//! the latency) as link cost", App. G.1). For each silo pair we derive:
//!
//! * `l(i,j)` — end-to-end latency: Σ over path links of `0.0085·km + 4` ms.
//! * `A(i',j')` — available bandwidth of the path. Two models:
//!   - [`BwModel::MinCapacity`]: `min` link capacity along the path —
//!     Eq. (3) taken literally (no background traffic).
//!   - [`BwModel::FairShare`] (default): capacity divided by the *static
//!     fair share* of routed pairs crossing the link, normalized by (N−1).
//!     With 1 Gbps cores this yields the tens-to-hundreds-of-Mbps spread on
//!     central links that the paper reports matching real measurements
//!     (footnote 3 + App. G Fig. 7); on a full mesh it degenerates to
//!     MinCapacity, exactly as the paper's synthetic underlays behave.

use super::geo::latency_ms;
use super::underlay::Underlay;
use crate::graph::shortest_path::{all_pairs, dijkstra};

/// Available-bandwidth model along routed paths.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BwModel {
    /// A(path) = min link capacity (Eq. (3) with empty core).
    MinCapacity,
    /// A(path) = min over links of C / max(1, pairs(link)/(N−1)).
    FairShare,
}

/// Precomputed per-pair routing products.
#[derive(Clone, Debug)]
pub struct Routes {
    /// end-to-end latency between silo i's and silo j's routers, ms.
    pub lat_ms: Vec<Vec<f64>>,
    /// available bandwidth A(i', j') in bit/s (unloaded / designer view).
    pub abw_bps: Vec<Vec<f64>>,
    /// hop count of the route (diagnostics / Fig. 7 reproduction).
    pub hops: Vec<Vec<usize>>,
    /// core-link edge indices of each route (empty = synthetic/no paths).
    pub paths: Vec<Vec<Vec<usize>>>,
    /// per-core-link capacities, bit/s (indexed by edge id).
    pub link_caps_bps: Vec<f64>,
}

impl Routes {
    /// Compute routes over `net` with a uniform core capacity.
    pub fn compute(net: &Underlay, core_capacity_bps: f64, model: BwModel) -> Routes {
        let caps = vec![core_capacity_bps; net.core.m()];
        Routes::compute_with_capacities(net, &caps, model)
    }

    /// Compute routes with per-link core capacities (len = net.core.m()).
    pub fn compute_with_capacities(
        net: &Underlay,
        link_caps_bps: &[f64],
        model: BwModel,
    ) -> Routes {
        let n = net.n_silos();
        assert_eq!(link_caps_bps.len(), net.core.m());
        let sp = all_pairs(&net.core);

        // Reconstruct edge sequences and count pair load per link.
        let mut link_load = vec![0usize; net.core.m()];
        let mut paths: Vec<Vec<Vec<usize>>> = vec![vec![Vec::new(); n]; n]; // edge indices
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let node_path = sp[i].path_to(j).expect("underlay connected");
                let mut edges = Vec::with_capacity(node_path.len() - 1);
                for w in node_path.windows(2) {
                    let eidx = net
                        .core
                        .neighbors(w[0])
                        .iter()
                        .find(|&&(v, _)| v == w[1])
                        .map(|&(_, e)| e)
                        .expect("path edge exists");
                    edges.push(eidx);
                }
                if i < j {
                    for &e in &edges {
                        link_load[e] += 1;
                    }
                }
                paths[i][j] = edges;
            }
        }

        // Effective per-link bandwidth under the chosen model.
        let eff: Vec<f64> = (0..net.core.m())
            .map(|e| match model {
                BwModel::MinCapacity => link_caps_bps[e],
                BwModel::FairShare => {
                    let share = (link_load[e] as f64 / (n.max(2) - 1) as f64).max(1.0);
                    link_caps_bps[e] / share
                }
            })
            .collect();

        let mut lat = vec![vec![0.0f64; n]; n];
        let mut abw = vec![vec![f64::INFINITY; n]; n];
        let mut hops = vec![vec![0usize; n]; n];
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    abw[i][j] = f64::INFINITY;
                    continue;
                }
                let mut l = 0.0;
                let mut a = f64::INFINITY;
                for &e in &paths[i][j] {
                    let (_, _, km) = net.core.edge(e);
                    l += latency_ms(km);
                    a = a.min(eff[e]);
                }
                lat[i][j] = l;
                abw[i][j] = a;
                hops[i][j] = paths[i][j].len();
            }
        }
        Routes {
            lat_ms: lat,
            abw_bps: abw,
            hops,
            paths,
            link_caps_bps: link_caps_bps.to_vec(),
        }
    }

    /// Congestion-aware per-arc available bandwidth for a set of concurrent
    /// flows (the arcs active in one synchronous round): each core link's
    /// capacity is split across the flows routed over it. This is what the
    /// paper's simulator realizes — the STAR's N inbound transfers pile onto
    /// the trunks around the hub, while tree/ring flows are mostly disjoint.
    /// Returns `A(flow)` in the same order as `flows`.
    pub fn concurrent_abw(&self, flows: &[(usize, usize)]) -> Vec<f64> {
        let mut load = vec![0u32; self.link_caps_bps.len()];
        for &(i, j) in flows {
            for &e in &self.paths[i][j] {
                load[e] += 1;
            }
        }
        flows
            .iter()
            .map(|&(i, j)| {
                let mut a = f64::INFINITY;
                for &e in &self.paths[i][j] {
                    a = a.min(self.link_caps_bps[e] / load[e].max(1) as f64);
                }
                a
            })
            .collect()
    }

    pub fn n(&self) -> usize {
        self.lat_ms.len()
    }

    /// Flattened off-diagonal available bandwidths (Fig. 7 distribution).
    pub fn abw_distribution(&self) -> Vec<f64> {
        let n = self.n();
        let mut v = Vec::with_capacity(n * (n - 1) / 2);
        for i in 0..n {
            for j in i + 1..n {
                v.push(self.abw_bps[i][j]);
            }
        }
        v
    }
}

/// Latency between two silos along the shortest route (standalone helper
/// used by designers that only need one pair).
pub fn pair_latency_ms(net: &Underlay, i: usize, j: usize) -> f64 {
    let sp = dijkstra(&net.core, i);
    let path = sp.path_to(j).expect("underlay connected");
    path.windows(2)
        .map(|w| {
            let km = net.core.weight(w[0], w[1]).unwrap();
            latency_ms(km)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_mesh_single_hop() {
        let net = Underlay::builtin("gaia").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::FairShare);
        for i in 0..net.n_silos() {
            for j in 0..net.n_silos() {
                if i != j {
                    assert_eq!(r.hops[i][j], 1, "full mesh routes direct");
                    // fair share degenerates to capacity on a mesh
                    assert!((r.abw_bps[i][j] - 1e9).abs() < 1.0);
                }
            }
        }
    }

    #[test]
    fn latency_symmetric_and_triangle() {
        let net = Underlay::builtin("geant").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::MinCapacity);
        let n = net.n_silos();
        for i in 0..n {
            assert_eq!(r.lat_ms[i][i], 0.0);
            for j in 0..n {
                assert!((r.lat_ms[i][j] - r.lat_ms[j][i]).abs() < 1e-9);
                for k in 0..n {
                    // routed latency is *approximately* a shortest-path
                    // metric: paths minimize distance, latency adds +4ms per
                    // hop, so allow the per-hop constant as slack.
                    assert!(
                        r.lat_ms[i][j] <= r.lat_ms[i][k] + r.lat_ms[k][j] + 4.0 * 10.0,
                        "triangle wildly violated {i}->{j}"
                    );
                }
            }
        }
    }

    #[test]
    fn min_capacity_uniform() {
        let net = Underlay::builtin("geant").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::MinCapacity);
        for x in r.abw_distribution() {
            assert!((x - 1e9).abs() < 1.0);
        }
    }

    #[test]
    fn fair_share_spreads_bandwidth_on_sparse_nets() {
        // Fig. 7 reproduction property: with 1 Gbps cores, Géant pair
        // bandwidths spread from tens/hundreds of Mbps (central trunks) up
        // to the full 1 Gbps (leaf links).
        let net = Underlay::builtin("geant").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::FairShare);
        let dist = r.abw_distribution();
        let min = dist.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = dist.iter().cloned().fold(0.0f64, f64::max);
        assert!(min < 0.5e9, "expected loaded trunks, min={min}");
        assert!(min > 1e7, "unrealistically starved link, min={min}");
        assert!(max > 0.9e9, "leaf pairs should see ~full capacity");
    }

    #[test]
    fn per_link_capacities_respected() {
        let net = Underlay::builtin("gaia").unwrap();
        let mut caps = vec![1e9; net.core.m()];
        caps[0] = 1e6; // throttle one direct link
        let r = Routes::compute_with_capacities(&net, &caps, BwModel::MinCapacity);
        let (u, v, _) = net.core.edge(0);
        // NB: routing minimizes distance, not bandwidth, so the throttled
        // direct link is still used by its endpoints.
        assert!((r.abw_bps[u][v] - 1e6).abs() < 1.0);
    }

    #[test]
    fn pair_latency_matches_routes() {
        let net = Underlay::builtin("geant").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::MinCapacity);
        for (i, j) in [(0, 5), (3, 17), (10, 30)] {
            let l = pair_latency_ms(&net, i, j);
            assert!((l - r.lat_ms[i][j]).abs() < 1e-9);
        }
    }

    #[test]
    fn hops_at_least_one() {
        let net = Underlay::builtin("ebone").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::FairShare);
        for i in 0..net.n_silos() {
            for j in 0..net.n_silos() {
                if i != j {
                    assert!(r.hops[i][j] >= 1);
                    assert!(r.lat_ms[i][j] >= 4.0, "at least one link's latency");
                }
            }
        }
    }
}
