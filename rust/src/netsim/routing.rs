//! Shortest-path routing + per-pair end-to-end latency and available
//! bandwidth — the measurable quantities MCT takes as input (Sect. 2.2).
//!
//! Routes follow latency-shortest paths over the core (the paper assumes
//! "shortest path routing with the geographical distance (or equivalently
//! the latency) as link cost", App. G.1). For each silo pair we derive:
//!
//! * `l(i,j)` — end-to-end latency: Σ over path links of `0.0085·km + 4` ms.
//! * `A(i',j')` — available bandwidth of the path. Two models:
//!   - [`BwModel::MinCapacity`]: `min` link capacity along the path —
//!     Eq. (3) taken literally (no background traffic).
//!   - [`BwModel::FairShare`] (default for the Fig.-7 diagnostic): capacity
//!     divided by the *static fair share* of routed pairs crossing the
//!     link, normalized by (N−1).
//!
//! ## Memory layout (PR 5)
//!
//! The per-pair products are **flat**: latencies and hop counts live in
//! [`Grid`]s (one allocation each), uniform-capacity MinCapacity bandwidth
//! collapses to a scalar (`A(i',j') = C` for every routed pair — exactly
//! what the dense matrix held, in O(1) words), and the per-pair edge paths
//! live in a single [`PathArena`] (per-pair offset ranges into one edge-id
//! array) instead of the old `Vec<Vec<Vec<usize>>>` — N² separate vectors
//! whose headers alone exceeded the payload. Total: O(N² + total-hops)
//! flat words, which is what lets `fedtopo scale` route 20 000-silo
//! underlays the nested layout could not hold. Past [`PATHS_MAX_N`] silos
//! the arena is skipped entirely (only the congestion *ablation* reads it;
//! `l`, `A`, and hop counts never need it after the sweep).
//!
//! Link loads are counted **during the Dijkstra sweep**: each source's
//! shortest-path tree is walked via predecessor edges straight out of the
//! heap pass — no node-path reconstruction, no per-pair allocation. The
//! pre-PR-5 nested implementation survives as [`dense`], the equivalence
//! oracle the tests pin the flat path against, bit for bit.
//!
//! ## Routing tiers (PR 7)
//!
//! Even one flat `f64` per ordered pair is ~20 GB at 50 000 silos, so the
//! grid itself is now one of three backends behind the same accessors:
//!
//! * **dense** (`N ≤ ROUTES_DENSE_MAX_N`) — the PR-5 flat grids, bit-exact,
//!   used automatically below the gate. Everything pinned before this PR
//!   (builtins, CI `synth:ba:2000` smoke, golden files) lives here and is
//!   byte-identical to before.
//! * **lazy-exact** ([`RoutingTier::LazyExact`], explicit opt-in) — no grid;
//!   one full Dijkstra *source row* is computed on first use and held in a
//!   fixed-capacity LRU. Every answer is bit-identical to the dense grid:
//!   the cache is pure memoization of a deterministic row, so capacity and
//!   eviction order can never change a result — **cache state is a
//!   performance switch, never semantics** (same contract as `--jobs`).
//! * **landmark** ([`RoutingTier::Landmark`], the default above the gate) —
//!   silos are binned into ~[`REGION_TARGET`]-sized geographic regions;
//!   one region member (nearest the centroid, ties to the lowest id)
//!   becomes the region's landmark. Intra-region queries are *exact*
//!   (truncated Dijkstra rows behind the same LRU — a truncated run's
//!   settled prefix is bit-identical to the full run's). Cross-region
//!   queries return the latency of the real detour walk
//!   `i → L(i) → L(j) → j` from O(N + R²) precomputed offsets — an upper
//!   approximation whose envelope `tests/routing_tiers.rs` pins against
//!   the dense oracle on seeded synth underlays.
//!
//! Construction cost of the landmark tier is R full Dijkstras (R ≈ N/64)
//! plus O(N) binning — no O(N²) product is ever materialized, which is what
//! lifts `netsim::synth::MAX_SILOS` to 100 000. The tiers only support the
//! uniform-capacity [`BwModel::MinCapacity`] model (the scalar-`A` case the
//! designers use); FairShare / heterogeneous capacities keep requiring the
//! dense backend and panic above the gate.
//!
//! The LRU capacity is a process-wide knob resolved at construction:
//! CLI `--route-cache` > `FEDTOPO_ROUTE_CACHE` > [`DEFAULT_ROW_CACHE_ROWS`]
//! (mirroring `util::parallel::jobs`), or per-instance via
//! [`Routes::compute_tiered`].
//!
//! ## Intra-cell parallelism (PR 10)
//!
//! The landmark build's per-region offset fills scatter on the intra-cell
//! pool (`util::parallel::run_intracell`) — each region writes disjoint
//! rows, so the merged bytes are identical for any worker count — and the
//! row LRU is striped by `source % S` ([`CACHE_STRIPES`]) with per-stripe
//! locks so parallel intra-region queries don't serialize globally.
//! Cache-miss Dijkstras run *outside* the stripe lock. All of it is a perf
//! switch, never semantics: capacity splitting, striping, and racing
//! duplicate computes can never change a result (`tests/routing_tiers.rs`
//! pins this).

use super::geo::{latency_ms, Site};
use super::underlay::Underlay;
use crate::graph::csr::Csr;
use crate::graph::shortest_path::dijkstra_to;
use crate::util::grid::Grid;
use crate::util::parallel::par_map_indexed;
use std::cell::RefCell;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrd};
use std::sync::{Mutex, OnceLock};

/// Largest silo count for which per-pair edge paths are materialized into
/// the [`PathArena`]. Beyond it `Routes::path` returns empty slices and the
/// congestion ablation falls back to static bandwidths — the O(N²·hops)
/// arena is the one product that cannot fit at 20 000+ silos, and nothing
/// on the design path needs it.
pub const PATHS_MAX_N: usize = 1024;

/// Largest silo count routed through the dense O(N²) grids. Above it
/// [`Routes::compute`] switches to the landmark tier (see module docs);
/// everything at or below stays byte-identical to the PR-5 layout.
pub const ROUTES_DENSE_MAX_N: usize = 4096;

/// Default number of source rows the lazy/landmark LRU holds when neither
/// `--route-cache` nor `FEDTOPO_ROUTE_CACHE` overrides it.
pub const DEFAULT_ROW_CACHE_ROWS: usize = 128;

/// Target silos per landmark region (the lat/lon binning aims for
/// ~N/REGION_TARGET regions; actual sizes follow site density).
pub const REGION_TARGET: usize = 64;

/// Explicit `--route-cache` override installed by the CLI (`0` = none).
static ROW_CACHE_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Install (or with `0` clear) the CLI-level row-cache capacity override.
/// Results are byte-identical for any capacity — see module docs.
pub fn set_row_cache_capacity(rows: usize) {
    ROW_CACHE_OVERRIDE.store(rows, AtomicOrd::Relaxed);
}

/// The effective LRU row capacity: CLI override > `FEDTOPO_ROUTE_CACHE` >
/// [`DEFAULT_ROW_CACHE_ROWS`]. Always ≥ 1. Read once per [`Routes`]
/// construction, like `util::parallel::jobs` at sweep dispatch.
pub fn row_cache_capacity() -> usize {
    match ROW_CACHE_OVERRIDE.load(AtomicOrd::Relaxed) {
        0 => default_row_cache_rows(),
        n => n,
    }
}

fn default_row_cache_rows() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        std::env::var("FEDTOPO_ROUTE_CACHE")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or(DEFAULT_ROW_CACHE_ROWS)
    })
}

/// Backend selection for [`Routes`] (see module docs for the contracts).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingTier {
    /// Flat O(N²) grids — bit-exact oracle, automatic at `N ≤`
    /// [`ROUTES_DENSE_MAX_N`].
    Dense,
    /// On-demand exact source rows behind the LRU; bit-identical to
    /// [`RoutingTier::Dense`] at any cache capacity.
    LazyExact,
    /// Exact intra-region, landmark detour across regions; automatic above
    /// the gate.
    Landmark,
}

/// Available-bandwidth model along routed paths.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BwModel {
    /// A(path) = min link capacity (Eq. (3) with empty core).
    MinCapacity,
    /// A(path) = min over links of C / max(1, pairs(link)/(N−1)).
    FairShare,
}

/// All per-pair core-link paths in one flat allocation: pair `(i, j)` owns
/// `edges[off[i·n+j] .. off[i·n+j+1]]` (edge ids into the underlay core, in
/// path order i → j). An *empty* arena (large N, or hand-built fixtures)
/// yields empty slices for every pair.
#[derive(Clone, Debug, Default)]
pub struct PathArena {
    n: usize,
    off: Vec<u32>,
    edges: Vec<u32>,
}

impl PathArena {
    /// The unmaterialized arena.
    pub fn empty(n: usize) -> PathArena {
        PathArena {
            n,
            off: Vec::new(),
            edges: Vec::new(),
        }
    }

    /// True when no paths are stored (every [`PathArena::path`] is empty).
    pub fn is_empty(&self) -> bool {
        self.off.is_empty()
    }

    /// Core-link edge ids of the route i → j (empty when unmaterialized or
    /// i == j).
    #[inline]
    pub fn path(&self, i: usize, j: usize) -> &[u32] {
        if self.off.is_empty() {
            return &[];
        }
        let p = i * self.n + j;
        &self.edges[self.off[p] as usize..self.off[p + 1] as usize]
    }

    /// Total stored hops across all pairs.
    pub fn total_hops(&self) -> usize {
        self.edges.len()
    }
}

/// Per-pair available bandwidth. Uniform-capacity MinCapacity networks —
/// every [`crate::netsim::delay::DelayModel::new`] — store the single
/// off-diagonal scalar the dense matrix used to replicate N² times.
#[derive(Clone, Debug)]
enum Abw {
    /// `A(i,j) = bps` for i ≠ j, ∞ on the diagonal.
    Uniform { bps: f64 },
    /// Fully general per-pair matrix (FairShare / heterogeneous capacities).
    Dense(Grid<f64>),
}

/// Per-pair latency/hop storage: the dense PR-5 grids below the gate, the
/// lazy/landmark tier above (see module docs).
#[derive(Clone, Debug)]
enum Backend {
    Dense {
        /// end-to-end latency between silo routers, ms (diagonal 0).
        lat: Grid<f64>,
        /// hop count of each route (diagnostics / Fig. 7 reproduction).
        hop: Grid<u32>,
    },
    Tiered(Box<Tiered>),
}

/// Precomputed per-pair routing products, flat-stored (see module docs).
#[derive(Clone, Debug)]
pub struct Routes {
    n: usize,
    /// latency + hop backend (dense grids or lazy/landmark tier).
    backend: Backend,
    /// available bandwidth A(i', j'), bit/s.
    abw: Abw,
    /// per-pair core-link edge paths (may be unmaterialized).
    paths: PathArena,
    /// per-core-link capacities, bit/s (indexed by edge id).
    link_caps_bps: Vec<f64>,
}

/// Min-heap item for the flat Dijkstra sweep — identical ordering to
/// `graph::shortest_path` (dist, then node id), so the predecessor trees
/// (and therefore every tie-broken route) match the dense oracle exactly.
#[derive(PartialEq)]
struct HeapItem {
    dist: f64,
    node: usize,
}
impl Eq for HeapItem {}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .dist
            .partial_cmp(&self.dist)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.node.cmp(&self.node))
    }
}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Reusable single-source state for the sweep: one Dijkstra pass filling
/// `dist` / `pred_node` / `pred_edge` (edge id used to reach each node) —
/// path reconstruction is then a pure predecessor walk, no neighbor scans.
struct Sweep {
    dist: Vec<f64>,
    pred_node: Vec<u32>,
    pred_edge: Vec<u32>,
    done: Vec<bool>,
    heap: BinaryHeap<HeapItem>,
    /// scratch for one pair's edge ids (reused across all pairs).
    chain: Vec<u32>,
}

impl Sweep {
    fn new(n: usize) -> Sweep {
        Sweep {
            dist: vec![f64::INFINITY; n],
            pred_node: vec![u32::MAX; n],
            pred_edge: vec![u32::MAX; n],
            done: vec![false; n],
            heap: BinaryHeap::new(),
            chain: Vec::new(),
        }
    }

    fn run(&mut self, core: &Csr, source: usize) {
        self.dist.fill(f64::INFINITY);
        self.pred_node.fill(u32::MAX);
        self.pred_edge.fill(u32::MAX);
        self.done.fill(false);
        self.heap.clear();
        self.dist[source] = 0.0;
        self.heap.push(HeapItem {
            dist: 0.0,
            node: source,
        });
        while let Some(HeapItem { dist: d, node: u }) = self.heap.pop() {
            if self.done[u] {
                continue;
            }
            self.done[u] = true;
            let (nbr, eid, w) = core.neighbors(u);
            for k in 0..nbr.len() {
                let v = nbr[k] as usize;
                let nd = d + w[k];
                if nd < self.dist[v] {
                    self.dist[v] = nd;
                    self.pred_node[v] = u as u32;
                    self.pred_edge[v] = eid[k];
                    self.heap.push(HeapItem { dist: nd, node: v });
                }
            }
        }
    }

    /// Fill `chain` with the edge ids of source → j, in path order.
    fn walk(&mut self, source: usize, j: usize) {
        self.chain.clear();
        let mut cur = j;
        while cur != source {
            let e = self.pred_edge[cur];
            assert!(e != u32::MAX, "underlay connected");
            self.chain.push(e);
            cur = self.pred_node[cur] as usize;
        }
        self.chain.reverse();
    }
}

/// Checked [`PathArena`] offset conversion: total stored hops are indexed
/// by u32, and a silent `as` truncation would corrupt every later path.
fn checked_off(len: usize) -> u32 {
    u32::try_from(len).unwrap_or_else(|_| {
        panic!(
            "PathArena offset overflow: {len} total stored hops exceed \
             u32::MAX — shrink the underlay or lower PATHS_MAX_N"
        )
    })
}

/// Epoch-tagged single-source Dijkstra for the tiered backend. Identical
/// relaxation and heap ordering to [`Sweep`] (so settled distances, trees,
/// and tie-broken routes match the dense oracle bit for bit), with two
/// twists: it can stop early once a target set has settled (a truncated
/// run's settled prefix is bit-identical to the full run's), and state is
/// reset by bumping an epoch instead of O(N) refills, so a cache-miss row
/// costs time proportional to what it explores.
struct TruncSweep {
    epoch: u64,
    /// node has a tentative distance this epoch.
    seen: Vec<u64>,
    /// node was settled this epoch.
    done: Vec<u64>,
    dist: Vec<f64>,
    pred_node: Vec<u32>,
    pred_edge: Vec<u32>,
    heap: BinaryHeap<HeapItem>,
    chain: Vec<u32>,
}

impl TruncSweep {
    fn new() -> TruncSweep {
        TruncSweep {
            epoch: 0,
            seen: Vec::new(),
            done: Vec::new(),
            dist: Vec::new(),
            pred_node: Vec::new(),
            pred_edge: Vec::new(),
            heap: BinaryHeap::new(),
            chain: Vec::new(),
        }
    }

    fn ensure(&mut self, n: usize) {
        if self.dist.len() < n {
            self.seen.resize(n, 0);
            self.done.resize(n, 0);
            self.dist.resize(n, f64::INFINITY);
            self.pred_node.resize(n, u32::MAX);
            self.pred_edge.resize(n, u32::MAX);
        }
    }

    /// Dijkstra from `source`, stopping once `remaining` nodes matching
    /// `is_target` have settled (pass `n` and `|_| true` for a full run).
    fn run(
        &mut self,
        core: &Csr,
        source: usize,
        mut remaining: usize,
        is_target: impl Fn(usize) -> bool,
    ) {
        self.epoch += 1;
        let ep = self.epoch;
        self.heap.clear();
        self.seen[source] = ep;
        self.dist[source] = 0.0;
        self.heap.push(HeapItem {
            dist: 0.0,
            node: source,
        });
        while let Some(HeapItem { dist: d, node: u }) = self.heap.pop() {
            if self.done[u] == ep {
                continue;
            }
            self.done[u] = ep;
            if is_target(u) {
                remaining -= 1;
                if remaining == 0 {
                    return;
                }
            }
            let (nbr, eid, w) = core.neighbors(u);
            for k in 0..nbr.len() {
                let v = nbr[k] as usize;
                let nd = d + w[k];
                if self.seen[v] != ep || nd < self.dist[v] {
                    self.seen[v] = ep;
                    self.dist[v] = nd;
                    self.pred_node[v] = u as u32;
                    self.pred_edge[v] = eid[k];
                    self.heap.push(HeapItem { dist: nd, node: v });
                }
            }
        }
    }

    /// Fill `chain` with the edge ids of source → j in path order
    /// (j must have settled this epoch).
    fn walk(&mut self, source: usize, j: usize) {
        debug_assert_eq!(self.done[j], self.epoch, "walk target not settled");
        self.chain.clear();
        let mut cur = j;
        while cur != source {
            let e = self.pred_edge[cur];
            assert!(e != u32::MAX, "underlay connected");
            self.chain.push(e);
            cur = self.pred_node[cur] as usize;
        }
        self.chain.reverse();
    }
}

thread_local! {
    /// Per-thread Dijkstra scratch for the tiered backend, reused across
    /// landmark sweeps and cache-miss rows: allocation volume scales with
    /// the worker count, not with N·R (gated by `benches/memory.rs`).
    /// Intra-cell pool workers are ordinary threads here: each keeps its
    /// own scratch, so parallel builds never share sweep state.
    static TIER_SCRATCH: RefCell<TruncSweep> = RefCell::new(TruncSweep::new());
}

/// A raw scatter target crossing the intra-cell dispatch (PR 10). Safety is
/// by disjointness: each region writes only its own rows/members.
struct ScatterPtr<T>(*mut T);
unsafe impl<T> Send for ScatterPtr<T> {}
unsafe impl<T> Sync for ScatterPtr<T> {}
impl<T> Clone for ScatterPtr<T> {
    fn clone(&self) -> Self {
        ScatterPtr(self.0)
    }
}
impl<T> Copy for ScatterPtr<T> {}

/// One cached exact source row: `lat`/`hop` parallel the (ascending)
/// member list of the source's region.
#[derive(Debug)]
struct CachedRow {
    source: u32,
    /// last-touch stamp for LRU eviction.
    stamp: u64,
    lat: Vec<f64>,
    hop: Vec<u32>,
}

#[derive(Debug, Default)]
struct CacheInner {
    stamp: u64,
    rows: Vec<CachedRow>,
}

/// Lock stripes in the row cache (PR 10). Queries from parallel landmark
/// builds and concurrent serve requests hash to `source % stripes`, so they
/// contend only when they touch the same stripe — never on one global lock.
const CACHE_STRIPES: usize = 8;

/// Fixed-capacity LRU of exact source rows, striped by source row
/// (`source % S`, S = `min(CACHE_STRIPES, capacity)` so every stripe holds
/// at least one row). The total capacity is split as evenly as possible
/// across stripes and eviction is per-stripe LRU. Rows are pure memoization
/// of a deterministic computation, so capacity, striping, and eviction
/// order are invisible in results — only in speed (pinned in
/// `tests/routing_tiers.rs`).
#[derive(Debug)]
struct RowCache {
    rows_cap: usize,
    stripes: Vec<Mutex<CacheInner>>,
}

impl RowCache {
    fn new(rows_cap: usize) -> RowCache {
        let rows_cap = rows_cap.max(1);
        let n_stripes = CACHE_STRIPES.min(rows_cap);
        RowCache {
            rows_cap,
            stripes: (0..n_stripes).map(|_| Mutex::new(CacheInner::default())).collect(),
        }
    }

    /// The stripe holding `source`'s row.
    #[inline]
    fn stripe_index(&self, source: usize) -> usize {
        source % self.stripes.len()
    }

    /// Row capacity of stripe `s`: the total split evenly, remainder to the
    /// lowest stripes. Sums to `rows_cap`; ≥ 1 because the stripe count
    /// never exceeds the capacity.
    fn stripe_cap(&self, s: usize) -> usize {
        let n = self.stripes.len();
        self.rows_cap / n + usize::from(s < self.rows_cap % n)
    }
}

impl Clone for RowCache {
    fn clone(&self) -> RowCache {
        // Cached rows are recomputable memoization — an empty cache is
        // semantically identical (cache-is-not-semantics contract).
        RowCache::new(self.rows_cap)
    }
}

/// The lazy/landmark backend: region structure, O(N + R²) landmark
/// offsets, and the LRU of exact rows. With a single region this *is* the
/// lazy-exact tier (rows are full, every query exact).
#[derive(Clone, Debug)]
struct Tiered {
    core: Csr,
    /// latency per core edge id, ms (`latency_ms(km)`, precomputed so row
    /// folds never touch the nested UnGraph).
    elat: Vec<f64>,
    /// region id per silo.
    region: Vec<u32>,
    /// silos of each region, ascending.
    members: Vec<Vec<u32>>,
    /// landmark silo of each region.
    landmarks: Vec<u32>,
    /// latency i → its landmark, ms (fold in path order i → L).
    to_lm: Vec<f64>,
    /// latency its landmark → i, ms (fold in path order L → i).
    from_lm: Vec<f64>,
    /// hops between i and its landmark.
    hop_lm: Vec<u32>,
    /// landmark → landmark latency, ms (R×R, diagonal 0).
    ll_lat: Grid<f64>,
    ll_hop: Grid<u32>,
    cache: RowCache,
}

/// Deterministic lat/lon grid binning into ~[`REGION_TARGET`]-sized
/// regions; landmark = member nearest the region centroid (ties to the
/// lowest silo id). Returns (region id per silo, members per region
/// ascending, landmark per region).
fn assign_regions(sites: &[Site]) -> (Vec<u32>, Vec<Vec<u32>>, Vec<u32>) {
    let n = sites.len();
    // rows × cols = 2b² bins ≈ n / REGION_TARGET (lon spans twice lat).
    let b = ((n as f64 / (2.0 * REGION_TARGET as f64)).sqrt().ceil() as usize).max(1);
    let (rows, cols) = (b, 2 * b);
    let bin_of = |s: &Site| {
        let br = ((s.lat + 90.0) / 180.0 * rows as f64).floor() as isize;
        let bc = ((s.lon + 180.0) / 360.0 * cols as f64).floor() as isize;
        let br = br.clamp(0, rows as isize - 1) as usize;
        let bc = bc.clamp(0, cols as isize - 1) as usize;
        br * cols + bc
    };
    let mut region_of_bin = vec![u32::MAX; rows * cols];
    let mut region = vec![0u32; n];
    let mut members: Vec<Vec<u32>> = Vec::new();
    for (i, s) in sites.iter().enumerate() {
        let bin = bin_of(s);
        if region_of_bin[bin] == u32::MAX {
            region_of_bin[bin] = members.len() as u32;
            members.push(Vec::new());
        }
        let r = region_of_bin[bin];
        region[i] = r;
        members[r as usize].push(i as u32);
    }
    let landmarks: Vec<u32> = members
        .iter()
        .map(|mem| {
            let inv = 1.0 / mem.len() as f64;
            let mut cla = 0.0;
            let mut clo = 0.0;
            for &i in mem {
                cla += sites[i as usize].lat;
                clo += sites[i as usize].lon;
            }
            let (cla, clo) = (cla * inv, clo * inv);
            let mut best = mem[0];
            let mut bd = f64::INFINITY;
            for &i in mem {
                let (dl, dn) = (sites[i as usize].lat - cla, sites[i as usize].lon - clo);
                let d = dl * dl + dn * dn;
                if d < bd {
                    bd = d;
                    best = i;
                }
            }
            best
        })
        .collect();
    (region, members, landmarks)
}

impl Tiered {
    /// Build the tier: R full Dijkstras (one per landmark, in parallel with
    /// the byte-identical ordered merge of `par_map_indexed`) fill the R×R
    /// landmark tables and each region's to/from-landmark offsets. No
    /// O(N²) product is materialized.
    fn build(net: &Underlay, tier: RoutingTier, cache_rows: usize) -> Tiered {
        let n = net.n_silos();
        let m = net.core.m();
        let core = Csr::from_ungraph(&net.core);
        let elat: Vec<f64> = (0..m).map(|e| latency_ms(net.core.edge(e).2)).collect();
        let (region, members, landmarks) = match tier {
            RoutingTier::LazyExact => (
                vec![0u32; n],
                vec![(0..n as u32).collect::<Vec<u32>>()],
                vec![0u32],
            ),
            RoutingTier::Landmark => assign_regions(&net.sites),
            RoutingTier::Dense => unreachable!("dense tier handled by caller"),
        };
        let r_count = landmarks.len();

        struct LmProducts {
            ll_lat: Vec<f64>,
            ll_hop: Vec<u32>,
            to: Vec<f64>,
            from: Vec<f64>,
            hop: Vec<u32>,
        }
        let per_lm: Vec<LmProducts> = par_map_indexed(&landmarks, |r, &lm| {
            TIER_SCRATCH.with(|s| {
                let mut sw = s.borrow_mut();
                sw.ensure(n);
                sw.run(&core, lm as usize, n, |_| true);
                let mem = &members[r];
                let mut p = LmProducts {
                    ll_lat: vec![0.0; r_count],
                    ll_hop: vec![0; r_count],
                    to: vec![0.0; mem.len()],
                    from: vec![0.0; mem.len()],
                    hop: vec![0; mem.len()],
                };
                for (s_idx, &ls) in landmarks.iter().enumerate() {
                    if s_idx == r {
                        continue;
                    }
                    sw.walk(lm as usize, ls as usize);
                    let mut f = 0.0;
                    for &e in &sw.chain {
                        f += elat[e as usize];
                    }
                    p.ll_lat[s_idx] = f;
                    p.ll_hop[s_idx] = sw.chain.len() as u32;
                }
                for (k, &i) in mem.iter().enumerate() {
                    if i == lm {
                        continue;
                    }
                    sw.walk(lm as usize, i as usize);
                    // from-fold runs L → i (chain order), to-fold runs the
                    // same tree path in i → L order: each is the latency of
                    // a real directed walk.
                    let mut f = 0.0;
                    for &e in &sw.chain {
                        f += elat[e as usize];
                    }
                    let mut t = 0.0;
                    for &e in sw.chain.iter().rev() {
                        t += elat[e as usize];
                    }
                    p.from[k] = f;
                    p.to[k] = t;
                    p.hop[k] = sw.chain.len() as u32;
                }
                p
            })
        });

        let mut ll_lat = Grid::filled(r_count, r_count, 0.0f64);
        let mut ll_hop = Grid::filled(r_count, r_count, 0u32);
        let mut to_lm = vec![0.0f64; n];
        let mut from_lm = vec![0.0f64; n];
        let mut hop_lm = vec![0u32; n];
        {
            // Per-region offset fills scatter on the intra-cell pool (PR 10):
            // region r writes only its own ll row and its own members'
            // offsets, so writes are disjoint and the merged bytes are
            // identical for any worker count (a pure placement of the
            // ordered `per_lm` results, merged by region index).
            let ll_lat_p = ScatterPtr(ll_lat.as_mut_slice().as_mut_ptr());
            let ll_hop_p = ScatterPtr(ll_hop.as_mut_slice().as_mut_ptr());
            let to_p = ScatterPtr(to_lm.as_mut_ptr());
            let from_p = ScatterPtr(from_lm.as_mut_ptr());
            let hop_p = ScatterPtr(hop_lm.as_mut_ptr());
            let (per_lm, members) = (&per_lm, &members);
            crate::util::parallel::run_intracell(r_count, |r| {
                let p = &per_lm[r];
                // SAFETY: region r's ll row and member silos are written by
                // exactly one part (regions partition the silos).
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        p.ll_lat.as_ptr(),
                        ll_lat_p.0.add(r * r_count),
                        r_count,
                    );
                    std::ptr::copy_nonoverlapping(
                        p.ll_hop.as_ptr(),
                        ll_hop_p.0.add(r * r_count),
                        r_count,
                    );
                    for (k, &i) in members[r].iter().enumerate() {
                        *to_p.0.add(i as usize) = p.to[k];
                        *from_p.0.add(i as usize) = p.from[k];
                        *hop_p.0.add(i as usize) = p.hop[k];
                    }
                }
            });
        }
        let cap = if cache_rows == 0 {
            row_cache_capacity()
        } else {
            cache_rows
        };
        Tiered {
            core,
            elat,
            region,
            members,
            landmarks,
            to_lm,
            from_lm,
            hop_lm,
            ll_lat,
            ll_hop,
            cache: RowCache::new(cap),
        }
    }

    #[inline]
    fn lat_hop(&self, i: usize, j: usize) -> (f64, u32) {
        if i == j {
            return (0.0, 0);
        }
        let ri = self.region[i] as usize;
        let rj = self.region[j] as usize;
        if ri == rj {
            self.exact_intra(i, j)
        } else {
            (
                self.to_lm[i] + self.ll_lat[(ri, rj)] + self.from_lm[j],
                self.hop_lm[i] + self.ll_hop[(ri, rj)] + self.hop_lm[j],
            )
        }
    }

    /// Exact intra-region answer from the LRU-cached truncated row. Misses
    /// run [`Tiered::compute_row`] *outside* the stripe lock, so concurrent
    /// misses (parallel landmark builds, concurrent serve requests) never
    /// serialize behind one another's Dijkstras; a racing duplicate insert
    /// is detected on re-lock and dropped (the rows are identical bytes, so
    /// either copy answers every future query the same way).
    fn exact_intra(&self, i: usize, j: usize) -> (f64, u32) {
        let r = self.region[i] as usize;
        let k = self.members[r]
            .binary_search(&(j as u32))
            .expect("intra-region query target is a region member");
        let s_idx = self.cache.stripe_index(i);
        let stripe = &self.cache.stripes[s_idx];
        {
            let mut inner = stripe.lock().expect("route row cache poisoned");
            inner.stamp += 1;
            let now = inner.stamp;
            if let Some(row) = inner.rows.iter_mut().find(|row| row.source == i as u32) {
                row.stamp = now;
                return (row.lat[k], row.hop[k]);
            }
        }
        let mut row = self.compute_row(i);
        let out = (row.lat[k], row.hop[k]);
        let mut inner = stripe.lock().expect("route row cache poisoned");
        inner.stamp += 1;
        row.stamp = inner.stamp;
        if !inner.rows.iter().any(|r2| r2.source == i as u32) {
            if inner.rows.len() >= self.cache.stripe_cap(s_idx) {
                let victim = inner
                    .rows
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, row)| row.stamp)
                    .map(|(x, _)| x)
                    .expect("cache nonempty at capacity");
                inner.rows.swap_remove(victim);
            }
            inner.rows.push(row);
        }
        out
    }

    /// One truncated Dijkstra from `i`, stopped once every member of i's
    /// region has settled; folds are bit-identical to the dense grid
    /// (settled-prefix property, same fold order).
    fn compute_row(&self, i: usize) -> CachedRow {
        let r = self.region[i] as usize;
        let mem = &self.members[r];
        let region = &self.region;
        TIER_SCRATCH.with(|s| {
            let mut sw = s.borrow_mut();
            sw.ensure(self.core.n());
            sw.run(&self.core, i, mem.len(), |u| region[u] as usize == r);
            let mut lat = vec![0.0f64; mem.len()];
            let mut hop = vec![0u32; mem.len()];
            for (k, &j) in mem.iter().enumerate() {
                if j as usize == i {
                    continue;
                }
                sw.walk(i, j as usize);
                let mut l = 0.0;
                for &e in &sw.chain {
                    l += self.elat[e as usize];
                }
                lat[k] = l;
                hop[k] = sw.chain.len() as u32;
            }
            CachedRow {
                source: i as u32,
                stamp: 0, // stamped at insert, under the stripe lock
                lat,
                hop,
            }
        })
    }
}

impl Routes {
    /// Compute routes over `net` with a uniform core capacity.
    pub fn compute(net: &Underlay, core_capacity_bps: f64, model: BwModel) -> Routes {
        let caps = vec![core_capacity_bps; net.core.m()];
        Routes::compute_with_capacities(net, &caps, model)
    }

    /// Compute routes with per-link core capacities (len = net.core.m()).
    /// Dispatches on the tier gate: dense grids at `N ≤`
    /// [`ROUTES_DENSE_MAX_N`] (byte-identical to the PR-5 layout), the
    /// landmark tier above it. The landmark tier supports only the
    /// uniform-capacity [`BwModel::MinCapacity`] model and panics
    /// otherwise — see module docs.
    pub fn compute_with_capacities(
        net: &Underlay,
        link_caps_bps: &[f64],
        model: BwModel,
    ) -> Routes {
        if net.n_silos() <= ROUTES_DENSE_MAX_N {
            Routes::compute_dense_backend(net, link_caps_bps, model)
        } else {
            Routes::compute_tiered_with_capacities(
                net,
                link_caps_bps,
                model,
                RoutingTier::Landmark,
                0,
            )
        }
    }

    /// Explicit-tier constructor (tests, benches, diagnostics): force a
    /// backend regardless of the size gate. `cache_rows = 0` resolves the
    /// LRU capacity via [`row_cache_capacity`]. Uniform-capacity
    /// MinCapacity only for the non-dense tiers.
    pub fn compute_tiered(
        net: &Underlay,
        core_capacity_bps: f64,
        tier: RoutingTier,
        cache_rows: usize,
    ) -> Routes {
        let caps = vec![core_capacity_bps; net.core.m()];
        match tier {
            RoutingTier::Dense => Routes::compute_dense_backend(net, &caps, BwModel::MinCapacity),
            _ => Routes::compute_tiered_with_capacities(
                net,
                &caps,
                BwModel::MinCapacity,
                tier,
                cache_rows,
            ),
        }
    }

    fn compute_tiered_with_capacities(
        net: &Underlay,
        link_caps_bps: &[f64],
        model: BwModel,
        tier: RoutingTier,
        cache_rows: usize,
    ) -> Routes {
        let n = net.n_silos();
        let m = net.core.m();
        assert_eq!(link_caps_bps.len(), m);
        let uniform = m > 0 && link_caps_bps.iter().all(|&c| c == link_caps_bps[0]);
        assert!(
            model == BwModel::MinCapacity && uniform,
            "routing tiers past ROUTES_DENSE_MAX_N={ROUTES_DENSE_MAX_N} support only \
             BwModel::MinCapacity with uniform core capacities (N={n}, model={model:?}, \
             uniform={uniform}); FairShare / heterogeneous capacities need the dense grids"
        );
        Routes {
            n,
            backend: Backend::Tiered(Box::new(Tiered::build(net, tier, cache_rows))),
            abw: Abw::Uniform {
                bps: link_caps_bps[0],
            },
            paths: PathArena::empty(n),
            link_caps_bps: link_caps_bps.to_vec(),
        }
    }

    /// The dense-grid build: ONE Dijkstra sweep fills every product.
    /// MinCapacity folds per-link capacity minima during the same
    /// predecessor walk that folds latency; FairShare (whose effective
    /// capacities need the *complete* link loads) keeps the predecessor
    /// trees and replays the chain walks afterwards — min-folds are
    /// order-insensitive, so both stay bit-identical to the [`dense`]
    /// oracle without ever re-running Dijkstra.
    fn compute_dense_backend(
        net: &Underlay,
        link_caps_bps: &[f64],
        model: BwModel,
    ) -> Routes {
        let n = net.n_silos();
        let m = net.core.m();
        assert_eq!(link_caps_bps.len(), m);
        let core = Csr::from_ungraph(&net.core);
        let materialize = n <= PATHS_MAX_N;

        let uniform = m > 0 && link_caps_bps.iter().all(|&c| c == link_caps_bps[0]);
        let scalar_abw = model == BwModel::MinCapacity && uniform;
        // Heterogeneous MinCapacity: eff = caps, known upfront — fold the
        // per-pair min during the first (only) sweep.
        let fold_caps = model == BwModel::MinCapacity && !scalar_abw;
        // Unmaterialized FairShare: keep the predecessor trees (2 transient
        // u32 grids) so the eff fold is a chain replay, not a second sweep.
        let keep_preds = model == BwModel::FairShare && !materialize;

        let mut lat = Grid::filled(n, n, 0.0f64);
        let mut hop = Grid::filled(n, n, 0u32);
        let mut abw_grid = if scalar_abw {
            None
        } else {
            Some(Grid::filled(n, n, f64::INFINITY))
        };
        let mut pred_grids = if keep_preds {
            Some((
                Grid::filled(n, n, u32::MAX),
                Grid::filled(n, n, u32::MAX),
            ))
        } else {
            None
        };
        let mut link_load = vec![0usize; m];
        let mut off: Vec<u32> = Vec::new();
        let mut arena_edges: Vec<u32> = Vec::new();
        if materialize {
            off.reserve(n * n + 1);
            off.push(0);
        }

        let mut sweep = Sweep::new(n);
        for i in 0..n {
            sweep.run(&core, i);
            if let Some((pn, pe)) = &mut pred_grids {
                pn.row_mut(i).copy_from_slice(&sweep.pred_node);
                pe.row_mut(i).copy_from_slice(&sweep.pred_edge);
            }
            for j in 0..n {
                if i == j {
                    if materialize {
                        off.push(checked_off(arena_edges.len()));
                    }
                    continue;
                }
                sweep.walk(i, j);
                // Latency accumulates in path order — the same fold the
                // dense oracle performs, so the sums are bit-identical.
                let mut l = 0.0f64;
                for &e in &sweep.chain {
                    let (_, _, km) = net.core.edge(e as usize);
                    l += latency_ms(km);
                }
                lat[(i, j)] = l;
                hop[(i, j)] = sweep.chain.len() as u32;
                if i < j {
                    for &e in &sweep.chain {
                        link_load[e as usize] += 1;
                    }
                }
                if fold_caps {
                    let mut a = f64::INFINITY;
                    for &e in &sweep.chain {
                        a = a.min(link_caps_bps[e as usize]);
                    }
                    abw_grid.as_mut().expect("fold_caps implies grid")[(i, j)] = a;
                }
                if materialize {
                    arena_edges.extend_from_slice(&sweep.chain);
                    off.push(checked_off(arena_edges.len()));
                }
            }
        }
        let paths = if materialize {
            PathArena {
                n,
                off,
                edges: arena_edges,
            }
        } else {
            PathArena::empty(n)
        };

        // Per-pair A(i',j') — collapsed to a scalar when every routed pair
        // provably sees the same value, folded during the sweep for
        // heterogeneous MinCapacity, and replayed off the stored
        // predecessor trees (or the arena) for FairShare.
        let abw = if scalar_abw {
            // min over ≥1 identical caps = that cap, for every i ≠ j.
            Abw::Uniform {
                bps: link_caps_bps[0],
            }
        } else if model == BwModel::MinCapacity {
            Abw::Dense(abw_grid.expect("folded during sweep"))
        } else {
            let eff: Vec<f64> = (0..m)
                .map(|e| {
                    let share = (link_load[e] as f64 / (n.max(2) - 1) as f64).max(1.0);
                    link_caps_bps[e] / share
                })
                .collect();
            let mut g = abw_grid.expect("FairShare is per-pair");
            if materialize {
                for i in 0..n {
                    for j in 0..n {
                        if i == j {
                            continue;
                        }
                        let mut a = f64::INFINITY;
                        for &e in paths.path(i, j) {
                            a = a.min(eff[e as usize]);
                        }
                        g[(i, j)] = a;
                    }
                }
            } else {
                // Chain replay off the stored trees: walks j → i, folding
                // the same edge set the oracle folds i → j — the min of a
                // set does not depend on fold order, so this is bit-exact.
                let (pn, pe) = pred_grids.as_ref().expect("kept for FairShare");
                for i in 0..n {
                    for j in 0..n {
                        if i == j {
                            continue;
                        }
                        let mut a = f64::INFINITY;
                        let mut cur = j;
                        while cur != i {
                            let e = pe[(i, cur)];
                            debug_assert!(e != u32::MAX, "underlay connected");
                            a = a.min(eff[e as usize]);
                            cur = pn[(i, cur)] as usize;
                        }
                        g[(i, j)] = a;
                    }
                }
            }
            Abw::Dense(g)
        };
        drop(pred_grids);

        Routes {
            n,
            backend: Backend::Dense { lat, hop },
            abw,
            paths,
            link_caps_bps: link_caps_bps.to_vec(),
        }
    }

    /// Hand-built fixture constructor (tests / tiny synthetic models):
    /// dense nested inputs, no paths.
    pub fn from_dense(
        lat_ms: &[Vec<f64>],
        abw_bps: &[Vec<f64>],
        hops: &[Vec<usize>],
        link_caps_bps: Vec<f64>,
    ) -> Routes {
        let n = lat_ms.len();
        let hops_u32: Vec<Vec<u32>> = hops
            .iter()
            .map(|r| r.iter().map(|&h| h as u32).collect())
            .collect();
        Routes {
            n,
            backend: Backend::Dense {
                lat: Grid::from_nested(lat_ms),
                hop: Grid::from_nested(&hops_u32),
            },
            abw: Abw::Dense(Grid::from_nested(abw_bps)),
            paths: PathArena::empty(n),
            link_caps_bps,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The active backend tier (a tiered backend with a single region *is*
    /// the lazy-exact tier — full rows, exact everywhere).
    pub fn tier(&self) -> RoutingTier {
        match &self.backend {
            Backend::Dense { .. } => RoutingTier::Dense,
            Backend::Tiered(t) if t.landmarks.len() == 1 => RoutingTier::LazyExact,
            Backend::Tiered(_) => RoutingTier::Landmark,
        }
    }

    /// Landmark silo ids, when the landmark tier is active with more than
    /// one region — designers (e.g. star hub selection) restrict O(N²)
    /// candidate scans to these.
    pub fn landmark_nodes(&self) -> Option<&[u32]> {
        match &self.backend {
            Backend::Tiered(t) if t.landmarks.len() > 1 => Some(&t.landmarks),
            _ => None,
        }
    }

    /// True when `lat_ms(i, j)` / `hops(i, j)` are exact (bit-identical to
    /// the dense oracle): always, except cross-region pairs of the
    /// landmark tier.
    pub fn exact_pair(&self, i: usize, j: usize) -> bool {
        match &self.backend {
            Backend::Dense { .. } => true,
            Backend::Tiered(t) => t.region[i] == t.region[j],
        }
    }

    /// Landmark detour offsets `(to_lm, from_lm)` of silo `i`, ms — the
    /// slack terms of the pinned cross-region approximation envelope.
    /// `None` on the dense backend.
    pub fn landmark_offsets_ms(&self, i: usize) -> Option<(f64, f64)> {
        match &self.backend {
            Backend::Dense { .. } => None,
            Backend::Tiered(t) => Some((t.to_lm[i], t.from_lm[i])),
        }
    }

    /// End-to-end latency between silo i's and silo j's routers, ms.
    #[inline]
    pub fn lat_ms(&self, i: usize, j: usize) -> f64 {
        match &self.backend {
            Backend::Dense { lat, .. } => lat[(i, j)],
            Backend::Tiered(t) => t.lat_hop(i, j).0,
        }
    }

    /// Available bandwidth A(i', j') in bit/s (unloaded / designer view).
    #[inline]
    pub fn abw_bps(&self, i: usize, j: usize) -> f64 {
        match &self.abw {
            Abw::Uniform { bps } => {
                if i == j {
                    f64::INFINITY
                } else {
                    *bps
                }
            }
            Abw::Dense(g) => g[(i, j)],
        }
    }

    /// Hop count of the route (diagnostics / Fig. 7 reproduction).
    #[inline]
    pub fn hops(&self, i: usize, j: usize) -> usize {
        match &self.backend {
            Backend::Dense { hop, .. } => hop[(i, j)] as usize,
            Backend::Tiered(t) => t.lat_hop(i, j).1 as usize,
        }
    }

    /// Core-link edge ids of the route i → j (empty when the arena is
    /// unmaterialized — see [`PATHS_MAX_N`]).
    #[inline]
    pub fn path(&self, i: usize, j: usize) -> &[u32] {
        self.paths.path(i, j)
    }

    /// True when per-pair edge paths are stored.
    pub fn has_paths(&self) -> bool {
        !self.paths.is_empty()
    }

    /// Per-core-link capacities, bit/s (indexed by edge id).
    pub fn link_caps_bps(&self) -> &[f64] {
        &self.link_caps_bps
    }

    /// Scale every available bandwidth by `mult` (scenario core
    /// perturbations re-scaling the measured model).
    pub fn scale_abw(&mut self, mult: f64) {
        match &mut self.abw {
            Abw::Uniform { bps } => *bps *= mult,
            Abw::Dense(g) => {
                for v in g.as_mut_slice() {
                    *v *= mult;
                }
            }
        }
    }

    /// Congestion-aware per-arc available bandwidth for a set of concurrent
    /// flows (the arcs active in one synchronous round): each core link's
    /// capacity is split across the flows routed over it. Requires a
    /// materialized [`PathArena`] (with an empty arena every flow reports
    /// ∞ — callers guard with [`Routes::has_paths`], as
    /// `DelayModel::arc_delays_congested` does). Returns `A(flow)` in the
    /// same order as `flows`.
    pub fn concurrent_abw(&self, flows: &[(usize, usize)]) -> Vec<f64> {
        let mut load = vec![0u32; self.link_caps_bps.len()];
        for &(i, j) in flows {
            for &e in self.paths.path(i, j) {
                load[e as usize] += 1;
            }
        }
        flows
            .iter()
            .map(|&(i, j)| {
                let mut a = f64::INFINITY;
                for &e in self.paths.path(i, j) {
                    a = a.min(self.link_caps_bps[e as usize] / load[e as usize].max(1) as f64);
                }
                a
            })
            .collect()
    }

    /// Flattened off-diagonal available bandwidths (Fig. 7 distribution).
    pub fn abw_distribution(&self) -> Vec<f64> {
        let n = self.n();
        let mut v = Vec::with_capacity(n * (n - 1) / 2);
        for i in 0..n {
            for j in i + 1..n {
                v.push(self.abw_bps(i, j));
            }
        }
        v
    }
}

/// Latency between two silos along the shortest route (standalone helper
/// used by designers that only need one pair). Uses the early-exit
/// Dijkstra — the run stops once `j` settles, and a settled prefix is
/// bit-identical to the full run, so the answer matches [`Routes`].
pub fn pair_latency_ms(net: &Underlay, i: usize, j: usize) -> f64 {
    let sp = dijkstra_to(&net.core, i, j);
    let path = sp.path_to(j).expect("underlay connected");
    path.windows(2)
        .map(|w| {
            let km = net.core.weight(w[0], w[1]).unwrap();
            latency_ms(km)
        })
        .sum()
}

/// The pre-PR-5 nested-storage implementation, kept verbatim as the
/// migration's equivalence oracle: `tests` (here and in
/// `tests/csr_equiv.rs`) pin the flat [`Routes`] bit-identical to it on
/// builtins and synthetic underlays. Do not grow features onto this path.
pub mod dense {
    use super::super::geo::latency_ms;
    use super::super::underlay::Underlay;
    use super::BwModel;
    use crate::graph::shortest_path::all_pairs;

    /// Nested-layout routing products (the old `Routes` fields).
    #[derive(Clone, Debug)]
    pub struct DenseRoutes {
        pub lat_ms: Vec<Vec<f64>>,
        pub abw_bps: Vec<Vec<f64>>,
        pub hops: Vec<Vec<usize>>,
        pub paths: Vec<Vec<Vec<usize>>>,
    }

    /// The original per-pair computation: all-pairs node paths, then edge
    /// reconstruction by neighbor scan, then per-pair folds.
    pub fn compute_with_capacities(
        net: &Underlay,
        link_caps_bps: &[f64],
        model: BwModel,
    ) -> DenseRoutes {
        let n = net.n_silos();
        assert_eq!(link_caps_bps.len(), net.core.m());
        let sp = all_pairs(&net.core);

        let mut link_load = vec![0usize; net.core.m()];
        let mut paths: Vec<Vec<Vec<usize>>> = vec![vec![Vec::new(); n]; n];
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let node_path = sp[i].path_to(j).expect("underlay connected");
                let mut edges = Vec::with_capacity(node_path.len() - 1);
                for w in node_path.windows(2) {
                    let eidx = net
                        .core
                        .neighbors(w[0])
                        .iter()
                        .find(|&&(v, _)| v == w[1])
                        .map(|&(_, e)| e)
                        .expect("path edge exists");
                    edges.push(eidx);
                }
                if i < j {
                    for &e in &edges {
                        link_load[e] += 1;
                    }
                }
                paths[i][j] = edges;
            }
        }

        let eff: Vec<f64> = (0..net.core.m())
            .map(|e| match model {
                BwModel::MinCapacity => link_caps_bps[e],
                BwModel::FairShare => {
                    let share = (link_load[e] as f64 / (n.max(2) - 1) as f64).max(1.0);
                    link_caps_bps[e] / share
                }
            })
            .collect();

        let mut lat = vec![vec![0.0f64; n]; n];
        let mut abw = vec![vec![f64::INFINITY; n]; n];
        let mut hops = vec![vec![0usize; n]; n];
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    abw[i][j] = f64::INFINITY;
                    continue;
                }
                let mut l = 0.0;
                let mut a = f64::INFINITY;
                for &e in &paths[i][j] {
                    let (_, _, km) = net.core.edge(e);
                    l += latency_ms(km);
                    a = a.min(eff[e]);
                }
                lat[i][j] = l;
                abw[i][j] = a;
                hops[i][j] = paths[i][j].len();
            }
        }
        DenseRoutes {
            lat_ms: lat,
            abw_bps: abw,
            hops,
            paths,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The flat sweep must reproduce the nested oracle bit for bit —
    /// latencies, bandwidths, hops, and (when materialized) the paths
    /// themselves.
    fn assert_matches_dense(net: &Underlay, caps: &[f64], model: BwModel) {
        let flat = Routes::compute_with_capacities(net, caps, model);
        let nested = dense::compute_with_capacities(net, caps, model);
        let n = net.n_silos();
        for i in 0..n {
            for j in 0..n {
                assert_eq!(
                    flat.lat_ms(i, j).to_bits(),
                    nested.lat_ms[i][j].to_bits(),
                    "lat ({i},{j})"
                );
                assert_eq!(
                    flat.abw_bps(i, j).to_bits(),
                    nested.abw_bps[i][j].to_bits(),
                    "abw ({i},{j})"
                );
                assert_eq!(flat.hops(i, j), nested.hops[i][j], "hops ({i},{j})");
                let fp: Vec<usize> =
                    flat.path(i, j).iter().map(|&e| e as usize).collect();
                assert_eq!(fp, nested.paths[i][j], "path ({i},{j})");
            }
        }
    }

    #[test]
    fn flat_matches_dense_oracle_on_builtins() {
        for name in ["gaia", "geant", "ebone"] {
            let net = Underlay::builtin(name).unwrap();
            let caps = vec![1e9; net.core.m()];
            assert_matches_dense(&net, &caps, BwModel::MinCapacity);
            assert_matches_dense(&net, &caps, BwModel::FairShare);
        }
    }

    #[test]
    fn flat_matches_dense_oracle_with_heterogeneous_caps() {
        let net = Underlay::builtin("geant").unwrap();
        let mut caps = vec![1e9; net.core.m()];
        caps[0] = 1e6;
        caps[3] = 5e8;
        assert_matches_dense(&net, &caps, BwModel::MinCapacity);
        assert_matches_dense(&net, &caps, BwModel::FairShare);
    }

    #[test]
    fn full_mesh_single_hop() {
        let net = Underlay::builtin("gaia").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::FairShare);
        for i in 0..net.n_silos() {
            for j in 0..net.n_silos() {
                if i != j {
                    assert_eq!(r.hops(i, j), 1, "full mesh routes direct");
                    // fair share degenerates to capacity on a mesh
                    assert!((r.abw_bps(i, j) - 1e9).abs() < 1.0);
                }
            }
        }
    }

    #[test]
    fn latency_symmetric_and_triangle() {
        let net = Underlay::builtin("geant").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::MinCapacity);
        let n = net.n_silos();
        for i in 0..n {
            assert_eq!(r.lat_ms(i, i), 0.0);
            for j in 0..n {
                assert!((r.lat_ms(i, j) - r.lat_ms(j, i)).abs() < 1e-9);
                for k in 0..n {
                    // routed latency is *approximately* a shortest-path
                    // metric: paths minimize distance, latency adds +4ms per
                    // hop, so allow the per-hop constant as slack.
                    assert!(
                        r.lat_ms(i, j) <= r.lat_ms(i, k) + r.lat_ms(k, j) + 4.0 * 10.0,
                        "triangle wildly violated {i}->{j}"
                    );
                }
            }
        }
    }

    #[test]
    fn min_capacity_uniform_collapses_to_scalar() {
        let net = Underlay::builtin("geant").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::MinCapacity);
        assert!(matches!(r.abw, Abw::Uniform { .. }));
        for x in r.abw_distribution() {
            assert!((x - 1e9).abs() < 1.0);
        }
        for i in 0..r.n() {
            assert!(r.abw_bps(i, i).is_infinite());
        }
    }

    #[test]
    fn fair_share_spreads_bandwidth_on_sparse_nets() {
        // Fig. 7 reproduction property: with 1 Gbps cores, Géant pair
        // bandwidths spread from tens/hundreds of Mbps (central trunks) up
        // to the full 1 Gbps (leaf links).
        let net = Underlay::builtin("geant").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::FairShare);
        let dist = r.abw_distribution();
        let min = dist.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = dist.iter().cloned().fold(0.0f64, f64::max);
        assert!(min < 0.5e9, "expected loaded trunks, min={min}");
        assert!(min > 1e7, "unrealistically starved link, min={min}");
        assert!(max > 0.9e9, "leaf pairs should see ~full capacity");
    }

    #[test]
    fn per_link_capacities_respected() {
        let net = Underlay::builtin("gaia").unwrap();
        let mut caps = vec![1e9; net.core.m()];
        caps[0] = 1e6; // throttle one direct link
        let r = Routes::compute_with_capacities(&net, &caps, BwModel::MinCapacity);
        let (u, v, _) = net.core.edge(0);
        // NB: routing minimizes distance, not bandwidth, so the throttled
        // direct link is still used by its endpoints.
        assert!((r.abw_bps(u, v) - 1e6).abs() < 1.0);
    }

    #[test]
    fn pair_latency_matches_routes() {
        let net = Underlay::builtin("geant").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::MinCapacity);
        for (i, j) in [(0, 5), (3, 17), (10, 30)] {
            let l = pair_latency_ms(&net, i, j);
            assert!((l - r.lat_ms(i, j)).abs() < 1e-9);
        }
    }

    #[test]
    fn hops_at_least_one() {
        let net = Underlay::builtin("ebone").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::FairShare);
        for i in 0..net.n_silos() {
            for j in 0..net.n_silos() {
                if i != j {
                    assert!(r.hops(i, j) >= 1);
                    assert!(r.lat_ms(i, j) >= 4.0, "at least one link's latency");
                }
            }
        }
    }

    #[test]
    fn big_n_skips_the_arena_but_keeps_products() {
        // Past PATHS_MAX_N the arena must be empty while latencies,
        // bandwidths, and hops stay identical to the materialized run on a
        // (smaller) identical network — here we just sanity-check the
        // degraded surface on a mid-size synthetic underlay.
        let net = Underlay::by_name(&format!("synth:grid:{}:seed7", PATHS_MAX_N + 5)).unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::MinCapacity);
        assert!(!r.has_paths());
        assert!(r.path(0, 1).is_empty());
        assert!(r.hops(0, 1) >= 1);
        assert!(r.lat_ms(0, 1) > 0.0);
        assert_eq!(r.abw_bps(0, 1), 1e9);
        // concurrent_abw degrades to ∞ (callers guard on has_paths)
        let a = r.concurrent_abw(&[(0, 1)]);
        assert!(a[0].is_infinite());
    }

    #[test]
    fn fair_share_without_arena_matches_dense_oracle() {
        // Force the unmaterialized second-sweep branch: N > PATHS_MAX_N so
        // no arena exists, FairShare so the Abw::Uniform shortcut doesn't
        // apply — A(i,j) must come from re-run predecessor-chain folds.
        // Pin the whole product set against the nested dense oracle.
        let spec = format!("synth:grid:{}:seed7", PATHS_MAX_N + 1);
        let net = Underlay::by_name(&spec).unwrap();
        let caps = vec![1e9; net.core.m()];
        let flat = Routes::compute_with_capacities(&net, &caps, BwModel::FairShare);
        assert!(!flat.has_paths(), "arena must be unmaterialized");
        assert!(matches!(flat.abw, Abw::Dense(_)), "FairShare is per-pair");
        let oracle = dense::compute_with_capacities(&net, &caps, BwModel::FairShare);
        let n = net.n_silos();
        for i in 0..n {
            for j in 0..n {
                assert_eq!(
                    flat.abw_bps(i, j).to_bits(),
                    oracle.abw_bps[i][j].to_bits(),
                    "abw ({i},{j})"
                );
                assert_eq!(
                    flat.lat_ms(i, j).to_bits(),
                    oracle.lat_ms[i][j].to_bits(),
                    "lat ({i},{j})"
                );
                assert_eq!(flat.hops(i, j), oracle.hops[i][j], "hops ({i},{j})");
            }
        }
    }

    #[test]
    #[should_panic(expected = "PathArena offset overflow")]
    fn arena_offset_overflow_panics() {
        // The guard replacing the silent `as u32` truncation.
        let _ = checked_off(u32::MAX as usize + 1);
    }

    #[test]
    fn checked_off_is_identity_in_range() {
        assert_eq!(checked_off(0), 0);
        assert_eq!(checked_off(u32::MAX as usize), u32::MAX);
    }

    #[test]
    fn one_sweep_abw_matches_dense_oracle_above_arena_gate() {
        // The satellite-2 pin at larger N: above PATHS_MAX_N no arena
        // exists, and A(i,j) must come from the single-sweep folds —
        // heterogeneous MinCapacity folds caps during the sweep, FairShare
        // replays the stored predecessor trees. Both bit-identical to the
        // nested oracle.
        let net = Underlay::by_name("synth:waxman:1100:seed7").unwrap();
        let mut caps = vec![1e9; net.core.m()];
        caps[0] = 1e6;
        caps[7] = 5e8;
        for model in [BwModel::MinCapacity, BwModel::FairShare] {
            let flat = Routes::compute_with_capacities(&net, &caps, model);
            assert!(!flat.has_paths(), "arena must be unmaterialized");
            assert!(matches!(flat.abw, Abw::Dense(_)), "per-pair abw");
            let oracle = dense::compute_with_capacities(&net, &caps, model);
            let n = net.n_silos();
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(
                        flat.abw_bps(i, j).to_bits(),
                        oracle.abw_bps[i][j].to_bits(),
                        "{model:?} abw ({i},{j})"
                    );
                    assert_eq!(
                        flat.lat_ms(i, j).to_bits(),
                        oracle.lat_ms[i][j].to_bits(),
                        "{model:?} lat ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn lazy_exact_tier_bit_equal_to_dense_small() {
        // The lazy tier is the dense grid computed one row at a time: on a
        // builtin (far below the gate, forced explicitly) every product is
        // bit-identical at a deliberately thrashing capacity of 1.
        let net = Underlay::builtin("geant").unwrap();
        let dense_r = Routes::compute(&net, 1e9, BwModel::MinCapacity);
        let lazy = Routes::compute_tiered(&net, 1e9, RoutingTier::LazyExact, 1);
        assert_eq!(lazy.tier(), RoutingTier::LazyExact);
        assert!(lazy.landmark_nodes().is_none());
        let n = net.n_silos();
        for i in 0..n {
            for j in 0..n {
                assert!(lazy.exact_pair(i, j));
                assert_eq!(
                    lazy.lat_ms(i, j).to_bits(),
                    dense_r.lat_ms(i, j).to_bits(),
                    "lat ({i},{j})"
                );
                assert_eq!(lazy.hops(i, j), dense_r.hops(i, j), "hops ({i},{j})");
                assert_eq!(
                    lazy.abw_bps(i, j).to_bits(),
                    dense_r.abw_bps(i, j).to_bits(),
                    "abw ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn tiered_construction_is_jobs_invariant() {
        // Landmark construction parallelizes over landmarks; the ordered
        // merge must make it byte-identical for any worker count.
        let _guard = crate::util::parallel::jobs_test_guard();
        let net = Underlay::by_name("synth:waxman:300:seed7").unwrap();
        crate::util::parallel::set_jobs(1);
        let a = Routes::compute_tiered(&net, 1e9, RoutingTier::Landmark, 8);
        crate::util::parallel::set_jobs(3);
        let b = Routes::compute_tiered(&net, 1e9, RoutingTier::Landmark, 8);
        crate::util::parallel::set_jobs(0);
        assert_eq!(a.tier(), RoutingTier::Landmark);
        let n = net.n_silos();
        for i in 0..n {
            for j in 0..n {
                assert_eq!(
                    a.lat_ms(i, j).to_bits(),
                    b.lat_ms(i, j).to_bits(),
                    "lat ({i},{j}) differs across --jobs"
                );
                assert_eq!(a.hops(i, j), b.hops(i, j), "hops ({i},{j})");
            }
        }
    }

    #[test]
    #[should_panic(expected = "routing tiers past ROUTES_DENSE_MAX_N")]
    fn fair_share_above_gate_panics() {
        let net = Underlay::by_name(&format!(
            "synth:ba:{}:seed7",
            ROUTES_DENSE_MAX_N + 1
        ))
        .unwrap();
        let _ = Routes::compute(&net, 1e9, BwModel::FairShare);
    }

    #[test]
    fn striped_cache_splits_capacity_exactly_and_keeps_every_stripe_nonempty() {
        for cap in [1usize, 2, 7, 8, 9, 64, 513] {
            let c = RowCache::new(cap);
            assert!(c.stripes.len() <= CACHE_STRIPES);
            assert!(c.stripes.len() <= cap, "stripes must not exceed capacity");
            let total: usize = (0..c.stripes.len()).map(|s| c.stripe_cap(s)).sum();
            assert_eq!(total, cap, "stripe caps must sum to the total");
            for s in 0..c.stripes.len() {
                assert!(c.stripe_cap(s) >= 1, "cap={cap} stripe {s} starved");
            }
        }
        // capacity 0 is clamped to 1, like the pre-stripe cache
        assert_eq!(RowCache::new(0).rows_cap, 1);
    }

    #[test]
    fn intra_region_results_invariant_to_striping_and_intracell_workers() {
        // Same queries through a thrashing 1-row cache (1 stripe), a
        // multi-stripe cache, and different intra-cell worker settings:
        // identical bytes every way (cache-is-not-semantics, and the
        // build's parallel scatter is placement-only).
        let _guard = crate::util::parallel::jobs_test_guard();
        let net = Underlay::by_name("synth:waxman:300:seed7").unwrap();
        let a = Routes::compute_tiered(&net, 1e9, RoutingTier::Landmark, 1);
        crate::util::parallel::set_intracell(5);
        let b = Routes::compute_tiered(&net, 1e9, RoutingTier::Landmark, 64);
        crate::util::parallel::set_intracell(0);
        let n = net.n_silos();
        for i in 0..n {
            for j in 0..n {
                assert_eq!(
                    a.lat_ms(i, j).to_bits(),
                    b.lat_ms(i, j).to_bits(),
                    "lat ({i},{j}) varies with cache striping"
                );
                assert_eq!(a.hops(i, j), b.hops(i, j), "hops ({i},{j})");
            }
        }
    }

    #[test]
    fn row_cache_capacity_override_resolves() {
        // Mirrors util::parallel::jobs: CLI override wins, 0 falls back to
        // env/default, and the result is always ≥ 1. (Capacity never
        // affects results — the other tests pin that.) The jobs guard
        // serializes every test that mutates a global CLI override.
        let _guard = crate::util::parallel::jobs_test_guard();
        set_row_cache_capacity(7);
        assert_eq!(row_cache_capacity(), 7);
        set_row_cache_capacity(0);
        assert!(row_cache_capacity() >= 1);
    }
}
