//! Shortest-path routing + per-pair end-to-end latency and available
//! bandwidth — the measurable quantities MCT takes as input (Sect. 2.2).
//!
//! Routes follow latency-shortest paths over the core (the paper assumes
//! "shortest path routing with the geographical distance (or equivalently
//! the latency) as link cost", App. G.1). For each silo pair we derive:
//!
//! * `l(i,j)` — end-to-end latency: Σ over path links of `0.0085·km + 4` ms.
//! * `A(i',j')` — available bandwidth of the path. Two models:
//!   - [`BwModel::MinCapacity`]: `min` link capacity along the path —
//!     Eq. (3) taken literally (no background traffic).
//!   - [`BwModel::FairShare`] (default for the Fig.-7 diagnostic): capacity
//!     divided by the *static fair share* of routed pairs crossing the
//!     link, normalized by (N−1).
//!
//! ## Memory layout (PR 5)
//!
//! The per-pair products are **flat**: latencies and hop counts live in
//! [`Grid`]s (one allocation each), uniform-capacity MinCapacity bandwidth
//! collapses to a scalar (`A(i',j') = C` for every routed pair — exactly
//! what the dense matrix held, in O(1) words), and the per-pair edge paths
//! live in a single [`PathArena`] (per-pair offset ranges into one edge-id
//! array) instead of the old `Vec<Vec<Vec<usize>>>` — N² separate vectors
//! whose headers alone exceeded the payload. Total: O(N² + total-hops)
//! flat words, which is what lets `fedtopo scale` route 20 000-silo
//! underlays the nested layout could not hold. Past [`PATHS_MAX_N`] silos
//! the arena is skipped entirely (only the congestion *ablation* reads it;
//! `l`, `A`, and hop counts never need it after the sweep).
//!
//! Link loads are counted **during the Dijkstra sweep**: each source's
//! shortest-path tree is walked via predecessor edges straight out of the
//! heap pass — no node-path reconstruction, no per-pair allocation. The
//! pre-PR-5 nested implementation survives as [`dense`], the equivalence
//! oracle the tests pin the flat path against, bit for bit.

use super::geo::latency_ms;
use super::underlay::Underlay;
use crate::graph::csr::Csr;
use crate::graph::shortest_path::dijkstra;
use crate::util::grid::Grid;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Largest silo count for which per-pair edge paths are materialized into
/// the [`PathArena`]. Beyond it `Routes::path` returns empty slices and the
/// congestion ablation falls back to static bandwidths — the O(N²·hops)
/// arena is the one product that cannot fit at 20 000+ silos, and nothing
/// on the design path needs it.
pub const PATHS_MAX_N: usize = 1024;

/// Available-bandwidth model along routed paths.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BwModel {
    /// A(path) = min link capacity (Eq. (3) with empty core).
    MinCapacity,
    /// A(path) = min over links of C / max(1, pairs(link)/(N−1)).
    FairShare,
}

/// All per-pair core-link paths in one flat allocation: pair `(i, j)` owns
/// `edges[off[i·n+j] .. off[i·n+j+1]]` (edge ids into the underlay core, in
/// path order i → j). An *empty* arena (large N, or hand-built fixtures)
/// yields empty slices for every pair.
#[derive(Clone, Debug, Default)]
pub struct PathArena {
    n: usize,
    off: Vec<u32>,
    edges: Vec<u32>,
}

impl PathArena {
    /// The unmaterialized arena.
    pub fn empty(n: usize) -> PathArena {
        PathArena {
            n,
            off: Vec::new(),
            edges: Vec::new(),
        }
    }

    /// True when no paths are stored (every [`PathArena::path`] is empty).
    pub fn is_empty(&self) -> bool {
        self.off.is_empty()
    }

    /// Core-link edge ids of the route i → j (empty when unmaterialized or
    /// i == j).
    #[inline]
    pub fn path(&self, i: usize, j: usize) -> &[u32] {
        if self.off.is_empty() {
            return &[];
        }
        let p = i * self.n + j;
        &self.edges[self.off[p] as usize..self.off[p + 1] as usize]
    }

    /// Total stored hops across all pairs.
    pub fn total_hops(&self) -> usize {
        self.edges.len()
    }
}

/// Per-pair available bandwidth. Uniform-capacity MinCapacity networks —
/// every [`crate::netsim::delay::DelayModel::new`] — store the single
/// off-diagonal scalar the dense matrix used to replicate N² times.
#[derive(Clone, Debug)]
enum Abw {
    /// `A(i,j) = bps` for i ≠ j, ∞ on the diagonal.
    Uniform { bps: f64 },
    /// Fully general per-pair matrix (FairShare / heterogeneous capacities).
    Dense(Grid<f64>),
}

/// Precomputed per-pair routing products, flat-stored (see module docs).
#[derive(Clone, Debug)]
pub struct Routes {
    n: usize,
    /// end-to-end latency between silo routers, ms (diagonal 0).
    lat: Grid<f64>,
    /// available bandwidth A(i', j'), bit/s.
    abw: Abw,
    /// hop count of each route (diagnostics / Fig. 7 reproduction).
    hop: Grid<u32>,
    /// per-pair core-link edge paths (may be unmaterialized).
    paths: PathArena,
    /// per-core-link capacities, bit/s (indexed by edge id).
    link_caps_bps: Vec<f64>,
}

/// Min-heap item for the flat Dijkstra sweep — identical ordering to
/// `graph::shortest_path` (dist, then node id), so the predecessor trees
/// (and therefore every tie-broken route) match the dense oracle exactly.
#[derive(PartialEq)]
struct HeapItem {
    dist: f64,
    node: usize,
}
impl Eq for HeapItem {}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .dist
            .partial_cmp(&self.dist)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.node.cmp(&self.node))
    }
}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Reusable single-source state for the sweep: one Dijkstra pass filling
/// `dist` / `pred_node` / `pred_edge` (edge id used to reach each node) —
/// path reconstruction is then a pure predecessor walk, no neighbor scans.
struct Sweep {
    dist: Vec<f64>,
    pred_node: Vec<u32>,
    pred_edge: Vec<u32>,
    done: Vec<bool>,
    heap: BinaryHeap<HeapItem>,
    /// scratch for one pair's edge ids (reused across all pairs).
    chain: Vec<u32>,
}

impl Sweep {
    fn new(n: usize) -> Sweep {
        Sweep {
            dist: vec![f64::INFINITY; n],
            pred_node: vec![u32::MAX; n],
            pred_edge: vec![u32::MAX; n],
            done: vec![false; n],
            heap: BinaryHeap::new(),
            chain: Vec::new(),
        }
    }

    fn run(&mut self, core: &Csr, source: usize) {
        self.dist.fill(f64::INFINITY);
        self.pred_node.fill(u32::MAX);
        self.pred_edge.fill(u32::MAX);
        self.done.fill(false);
        self.heap.clear();
        self.dist[source] = 0.0;
        self.heap.push(HeapItem {
            dist: 0.0,
            node: source,
        });
        while let Some(HeapItem { dist: d, node: u }) = self.heap.pop() {
            if self.done[u] {
                continue;
            }
            self.done[u] = true;
            let (nbr, eid, w) = core.neighbors(u);
            for k in 0..nbr.len() {
                let v = nbr[k] as usize;
                let nd = d + w[k];
                if nd < self.dist[v] {
                    self.dist[v] = nd;
                    self.pred_node[v] = u as u32;
                    self.pred_edge[v] = eid[k];
                    self.heap.push(HeapItem { dist: nd, node: v });
                }
            }
        }
    }

    /// Fill `chain` with the edge ids of source → j, in path order.
    fn walk(&mut self, source: usize, j: usize) {
        self.chain.clear();
        let mut cur = j;
        while cur != source {
            let e = self.pred_edge[cur];
            assert!(e != u32::MAX, "underlay connected");
            self.chain.push(e);
            cur = self.pred_node[cur] as usize;
        }
        self.chain.reverse();
    }
}

impl Routes {
    /// Compute routes over `net` with a uniform core capacity.
    pub fn compute(net: &Underlay, core_capacity_bps: f64, model: BwModel) -> Routes {
        let caps = vec![core_capacity_bps; net.core.m()];
        Routes::compute_with_capacities(net, &caps, model)
    }

    /// Compute routes with per-link core capacities (len = net.core.m()).
    pub fn compute_with_capacities(
        net: &Underlay,
        link_caps_bps: &[f64],
        model: BwModel,
    ) -> Routes {
        let n = net.n_silos();
        let m = net.core.m();
        assert_eq!(link_caps_bps.len(), m);
        let core = Csr::from_ungraph(&net.core);
        let materialize = n <= PATHS_MAX_N;

        let mut lat = Grid::filled(n, n, 0.0f64);
        let mut hop = Grid::filled(n, n, 0u32);
        let mut link_load = vec![0usize; m];
        let mut off: Vec<u32> = Vec::new();
        let mut arena_edges: Vec<u32> = Vec::new();
        if materialize {
            off.reserve(n * n + 1);
            off.push(0);
        }

        let mut sweep = Sweep::new(n);
        for i in 0..n {
            sweep.run(&core, i);
            for j in 0..n {
                if i == j {
                    if materialize {
                        off.push(arena_edges.len() as u32);
                    }
                    continue;
                }
                sweep.walk(i, j);
                // Latency accumulates in path order — the same fold the
                // dense oracle performs, so the sums are bit-identical.
                let mut l = 0.0f64;
                for &e in &sweep.chain {
                    let (_, _, km) = net.core.edge(e as usize);
                    l += latency_ms(km);
                }
                lat[(i, j)] = l;
                hop[(i, j)] = sweep.chain.len() as u32;
                if i < j {
                    for &e in &sweep.chain {
                        link_load[e as usize] += 1;
                    }
                }
                if materialize {
                    arena_edges.extend_from_slice(&sweep.chain);
                    off.push(arena_edges.len() as u32);
                }
            }
        }
        let paths = if materialize {
            PathArena {
                n,
                off,
                edges: arena_edges,
            }
        } else {
            PathArena::empty(n)
        };

        // Effective per-link bandwidth under the chosen model, then the
        // per-pair A(i',j') — collapsed to a scalar when every routed pair
        // provably sees the same value.
        let uniform = m > 0 && link_caps_bps.iter().all(|&c| c == link_caps_bps[0]);
        let abw = if model == BwModel::MinCapacity && uniform {
            // min over ≥1 identical caps = that cap, for every i ≠ j.
            Abw::Uniform {
                bps: link_caps_bps[0],
            }
        } else {
            let eff: Vec<f64> = (0..m)
                .map(|e| match model {
                    BwModel::MinCapacity => link_caps_bps[e],
                    BwModel::FairShare => {
                        let share =
                            (link_load[e] as f64 / (n.max(2) - 1) as f64).max(1.0);
                        link_caps_bps[e] / share
                    }
                })
                .collect();
            let mut g = Grid::filled(n, n, f64::INFINITY);
            if materialize {
                for i in 0..n {
                    for j in 0..n {
                        if i == j {
                            continue;
                        }
                        let mut a = f64::INFINITY;
                        for &e in paths.path(i, j) {
                            a = a.min(eff[e as usize]);
                        }
                        g[(i, j)] = a;
                    }
                }
            } else {
                // Unmaterialized arena: second sweep, folding eff mins
                // straight off the predecessor chains.
                for i in 0..n {
                    sweep.run(&core, i);
                    for j in 0..n {
                        if i == j {
                            continue;
                        }
                        sweep.walk(i, j);
                        let mut a = f64::INFINITY;
                        for &e in &sweep.chain {
                            a = a.min(eff[e as usize]);
                        }
                        g[(i, j)] = a;
                    }
                }
            }
            Abw::Dense(g)
        };

        Routes {
            n,
            lat,
            abw,
            hop,
            paths,
            link_caps_bps: link_caps_bps.to_vec(),
        }
    }

    /// Hand-built fixture constructor (tests / tiny synthetic models):
    /// dense nested inputs, no paths.
    pub fn from_dense(
        lat_ms: &[Vec<f64>],
        abw_bps: &[Vec<f64>],
        hops: &[Vec<usize>],
        link_caps_bps: Vec<f64>,
    ) -> Routes {
        let n = lat_ms.len();
        let hops_u32: Vec<Vec<u32>> = hops
            .iter()
            .map(|r| r.iter().map(|&h| h as u32).collect())
            .collect();
        Routes {
            n,
            lat: Grid::from_nested(lat_ms),
            abw: Abw::Dense(Grid::from_nested(abw_bps)),
            hop: Grid::from_nested(&hops_u32),
            paths: PathArena::empty(n),
            link_caps_bps,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// End-to-end latency between silo i's and silo j's routers, ms.
    #[inline]
    pub fn lat_ms(&self, i: usize, j: usize) -> f64 {
        self.lat[(i, j)]
    }

    /// Available bandwidth A(i', j') in bit/s (unloaded / designer view).
    #[inline]
    pub fn abw_bps(&self, i: usize, j: usize) -> f64 {
        match &self.abw {
            Abw::Uniform { bps } => {
                if i == j {
                    f64::INFINITY
                } else {
                    *bps
                }
            }
            Abw::Dense(g) => g[(i, j)],
        }
    }

    /// Hop count of the route (diagnostics / Fig. 7 reproduction).
    #[inline]
    pub fn hops(&self, i: usize, j: usize) -> usize {
        self.hop[(i, j)] as usize
    }

    /// Core-link edge ids of the route i → j (empty when the arena is
    /// unmaterialized — see [`PATHS_MAX_N`]).
    #[inline]
    pub fn path(&self, i: usize, j: usize) -> &[u32] {
        self.paths.path(i, j)
    }

    /// True when per-pair edge paths are stored.
    pub fn has_paths(&self) -> bool {
        !self.paths.is_empty()
    }

    /// Per-core-link capacities, bit/s (indexed by edge id).
    pub fn link_caps_bps(&self) -> &[f64] {
        &self.link_caps_bps
    }

    /// Scale every available bandwidth by `mult` (scenario core
    /// perturbations re-scaling the measured model).
    pub fn scale_abw(&mut self, mult: f64) {
        match &mut self.abw {
            Abw::Uniform { bps } => *bps *= mult,
            Abw::Dense(g) => {
                for v in g.as_mut_slice() {
                    *v *= mult;
                }
            }
        }
    }

    /// Congestion-aware per-arc available bandwidth for a set of concurrent
    /// flows (the arcs active in one synchronous round): each core link's
    /// capacity is split across the flows routed over it. Requires a
    /// materialized [`PathArena`] (with an empty arena every flow reports
    /// ∞ — callers guard with [`Routes::has_paths`], as
    /// `DelayModel::arc_delays_congested` does). Returns `A(flow)` in the
    /// same order as `flows`.
    pub fn concurrent_abw(&self, flows: &[(usize, usize)]) -> Vec<f64> {
        let mut load = vec![0u32; self.link_caps_bps.len()];
        for &(i, j) in flows {
            for &e in self.paths.path(i, j) {
                load[e as usize] += 1;
            }
        }
        flows
            .iter()
            .map(|&(i, j)| {
                let mut a = f64::INFINITY;
                for &e in self.paths.path(i, j) {
                    a = a.min(self.link_caps_bps[e as usize] / load[e as usize].max(1) as f64);
                }
                a
            })
            .collect()
    }

    /// Flattened off-diagonal available bandwidths (Fig. 7 distribution).
    pub fn abw_distribution(&self) -> Vec<f64> {
        let n = self.n();
        let mut v = Vec::with_capacity(n * (n - 1) / 2);
        for i in 0..n {
            for j in i + 1..n {
                v.push(self.abw_bps(i, j));
            }
        }
        v
    }
}

/// Latency between two silos along the shortest route (standalone helper
/// used by designers that only need one pair).
pub fn pair_latency_ms(net: &Underlay, i: usize, j: usize) -> f64 {
    let sp = dijkstra(&net.core, i);
    let path = sp.path_to(j).expect("underlay connected");
    path.windows(2)
        .map(|w| {
            let km = net.core.weight(w[0], w[1]).unwrap();
            latency_ms(km)
        })
        .sum()
}

/// The pre-PR-5 nested-storage implementation, kept verbatim as the
/// migration's equivalence oracle: `tests` (here and in
/// `tests/csr_equiv.rs`) pin the flat [`Routes`] bit-identical to it on
/// builtins and synthetic underlays. Do not grow features onto this path.
pub mod dense {
    use super::super::geo::latency_ms;
    use super::super::underlay::Underlay;
    use super::BwModel;
    use crate::graph::shortest_path::all_pairs;

    /// Nested-layout routing products (the old `Routes` fields).
    #[derive(Clone, Debug)]
    pub struct DenseRoutes {
        pub lat_ms: Vec<Vec<f64>>,
        pub abw_bps: Vec<Vec<f64>>,
        pub hops: Vec<Vec<usize>>,
        pub paths: Vec<Vec<Vec<usize>>>,
    }

    /// The original per-pair computation: all-pairs node paths, then edge
    /// reconstruction by neighbor scan, then per-pair folds.
    pub fn compute_with_capacities(
        net: &Underlay,
        link_caps_bps: &[f64],
        model: BwModel,
    ) -> DenseRoutes {
        let n = net.n_silos();
        assert_eq!(link_caps_bps.len(), net.core.m());
        let sp = all_pairs(&net.core);

        let mut link_load = vec![0usize; net.core.m()];
        let mut paths: Vec<Vec<Vec<usize>>> = vec![vec![Vec::new(); n]; n];
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let node_path = sp[i].path_to(j).expect("underlay connected");
                let mut edges = Vec::with_capacity(node_path.len() - 1);
                for w in node_path.windows(2) {
                    let eidx = net
                        .core
                        .neighbors(w[0])
                        .iter()
                        .find(|&&(v, _)| v == w[1])
                        .map(|&(_, e)| e)
                        .expect("path edge exists");
                    edges.push(eidx);
                }
                if i < j {
                    for &e in &edges {
                        link_load[e] += 1;
                    }
                }
                paths[i][j] = edges;
            }
        }

        let eff: Vec<f64> = (0..net.core.m())
            .map(|e| match model {
                BwModel::MinCapacity => link_caps_bps[e],
                BwModel::FairShare => {
                    let share = (link_load[e] as f64 / (n.max(2) - 1) as f64).max(1.0);
                    link_caps_bps[e] / share
                }
            })
            .collect();

        let mut lat = vec![vec![0.0f64; n]; n];
        let mut abw = vec![vec![f64::INFINITY; n]; n];
        let mut hops = vec![vec![0usize; n]; n];
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    abw[i][j] = f64::INFINITY;
                    continue;
                }
                let mut l = 0.0;
                let mut a = f64::INFINITY;
                for &e in &paths[i][j] {
                    let (_, _, km) = net.core.edge(e);
                    l += latency_ms(km);
                    a = a.min(eff[e]);
                }
                lat[i][j] = l;
                abw[i][j] = a;
                hops[i][j] = paths[i][j].len();
            }
        }
        DenseRoutes {
            lat_ms: lat,
            abw_bps: abw,
            hops,
            paths,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The flat sweep must reproduce the nested oracle bit for bit —
    /// latencies, bandwidths, hops, and (when materialized) the paths
    /// themselves.
    fn assert_matches_dense(net: &Underlay, caps: &[f64], model: BwModel) {
        let flat = Routes::compute_with_capacities(net, caps, model);
        let nested = dense::compute_with_capacities(net, caps, model);
        let n = net.n_silos();
        for i in 0..n {
            for j in 0..n {
                assert_eq!(
                    flat.lat_ms(i, j).to_bits(),
                    nested.lat_ms[i][j].to_bits(),
                    "lat ({i},{j})"
                );
                assert_eq!(
                    flat.abw_bps(i, j).to_bits(),
                    nested.abw_bps[i][j].to_bits(),
                    "abw ({i},{j})"
                );
                assert_eq!(flat.hops(i, j), nested.hops[i][j], "hops ({i},{j})");
                let fp: Vec<usize> =
                    flat.path(i, j).iter().map(|&e| e as usize).collect();
                assert_eq!(fp, nested.paths[i][j], "path ({i},{j})");
            }
        }
    }

    #[test]
    fn flat_matches_dense_oracle_on_builtins() {
        for name in ["gaia", "geant", "ebone"] {
            let net = Underlay::builtin(name).unwrap();
            let caps = vec![1e9; net.core.m()];
            assert_matches_dense(&net, &caps, BwModel::MinCapacity);
            assert_matches_dense(&net, &caps, BwModel::FairShare);
        }
    }

    #[test]
    fn flat_matches_dense_oracle_with_heterogeneous_caps() {
        let net = Underlay::builtin("geant").unwrap();
        let mut caps = vec![1e9; net.core.m()];
        caps[0] = 1e6;
        caps[3] = 5e8;
        assert_matches_dense(&net, &caps, BwModel::MinCapacity);
        assert_matches_dense(&net, &caps, BwModel::FairShare);
    }

    #[test]
    fn full_mesh_single_hop() {
        let net = Underlay::builtin("gaia").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::FairShare);
        for i in 0..net.n_silos() {
            for j in 0..net.n_silos() {
                if i != j {
                    assert_eq!(r.hops(i, j), 1, "full mesh routes direct");
                    // fair share degenerates to capacity on a mesh
                    assert!((r.abw_bps(i, j) - 1e9).abs() < 1.0);
                }
            }
        }
    }

    #[test]
    fn latency_symmetric_and_triangle() {
        let net = Underlay::builtin("geant").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::MinCapacity);
        let n = net.n_silos();
        for i in 0..n {
            assert_eq!(r.lat_ms(i, i), 0.0);
            for j in 0..n {
                assert!((r.lat_ms(i, j) - r.lat_ms(j, i)).abs() < 1e-9);
                for k in 0..n {
                    // routed latency is *approximately* a shortest-path
                    // metric: paths minimize distance, latency adds +4ms per
                    // hop, so allow the per-hop constant as slack.
                    assert!(
                        r.lat_ms(i, j) <= r.lat_ms(i, k) + r.lat_ms(k, j) + 4.0 * 10.0,
                        "triangle wildly violated {i}->{j}"
                    );
                }
            }
        }
    }

    #[test]
    fn min_capacity_uniform_collapses_to_scalar() {
        let net = Underlay::builtin("geant").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::MinCapacity);
        assert!(matches!(r.abw, Abw::Uniform { .. }));
        for x in r.abw_distribution() {
            assert!((x - 1e9).abs() < 1.0);
        }
        for i in 0..r.n() {
            assert!(r.abw_bps(i, i).is_infinite());
        }
    }

    #[test]
    fn fair_share_spreads_bandwidth_on_sparse_nets() {
        // Fig. 7 reproduction property: with 1 Gbps cores, Géant pair
        // bandwidths spread from tens/hundreds of Mbps (central trunks) up
        // to the full 1 Gbps (leaf links).
        let net = Underlay::builtin("geant").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::FairShare);
        let dist = r.abw_distribution();
        let min = dist.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = dist.iter().cloned().fold(0.0f64, f64::max);
        assert!(min < 0.5e9, "expected loaded trunks, min={min}");
        assert!(min > 1e7, "unrealistically starved link, min={min}");
        assert!(max > 0.9e9, "leaf pairs should see ~full capacity");
    }

    #[test]
    fn per_link_capacities_respected() {
        let net = Underlay::builtin("gaia").unwrap();
        let mut caps = vec![1e9; net.core.m()];
        caps[0] = 1e6; // throttle one direct link
        let r = Routes::compute_with_capacities(&net, &caps, BwModel::MinCapacity);
        let (u, v, _) = net.core.edge(0);
        // NB: routing minimizes distance, not bandwidth, so the throttled
        // direct link is still used by its endpoints.
        assert!((r.abw_bps(u, v) - 1e6).abs() < 1.0);
    }

    #[test]
    fn pair_latency_matches_routes() {
        let net = Underlay::builtin("geant").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::MinCapacity);
        for (i, j) in [(0, 5), (3, 17), (10, 30)] {
            let l = pair_latency_ms(&net, i, j);
            assert!((l - r.lat_ms(i, j)).abs() < 1e-9);
        }
    }

    #[test]
    fn hops_at_least_one() {
        let net = Underlay::builtin("ebone").unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::FairShare);
        for i in 0..net.n_silos() {
            for j in 0..net.n_silos() {
                if i != j {
                    assert!(r.hops(i, j) >= 1);
                    assert!(r.lat_ms(i, j) >= 4.0, "at least one link's latency");
                }
            }
        }
    }

    #[test]
    fn big_n_skips_the_arena_but_keeps_products() {
        // Past PATHS_MAX_N the arena must be empty while latencies,
        // bandwidths, and hops stay identical to the materialized run on a
        // (smaller) identical network — here we just sanity-check the
        // degraded surface on a mid-size synthetic underlay.
        let net = Underlay::by_name(&format!("synth:grid:{}:seed7", PATHS_MAX_N + 5)).unwrap();
        let r = Routes::compute(&net, 1e9, BwModel::MinCapacity);
        assert!(!r.has_paths());
        assert!(r.path(0, 1).is_empty());
        assert!(r.hops(0, 1) >= 1);
        assert!(r.lat_ms(0, 1) > 0.0);
        assert_eq!(r.abw_bps(0, 1), 1e9);
        // concurrent_abw degrades to ∞ (callers guard on has_paths)
        let a = r.concurrent_abw(&[(0, 1)]);
        assert!(a[0].is_infinite());
    }

    #[test]
    fn fair_share_without_arena_matches_dense_oracle() {
        // Force the unmaterialized second-sweep branch: N > PATHS_MAX_N so
        // no arena exists, FairShare so the Abw::Uniform shortcut doesn't
        // apply — A(i,j) must come from re-run predecessor-chain folds.
        // Pin the whole product set against the nested dense oracle.
        let spec = format!("synth:grid:{}:seed7", PATHS_MAX_N + 1);
        let net = Underlay::by_name(&spec).unwrap();
        let caps = vec![1e9; net.core.m()];
        let flat = Routes::compute_with_capacities(&net, &caps, BwModel::FairShare);
        assert!(!flat.has_paths(), "arena must be unmaterialized");
        assert!(matches!(flat.abw, Abw::Dense(_)), "FairShare is per-pair");
        let oracle = dense::compute_with_capacities(&net, &caps, BwModel::FairShare);
        let n = net.n_silos();
        for i in 0..n {
            for j in 0..n {
                assert_eq!(
                    flat.abw_bps(i, j).to_bits(),
                    oracle.abw_bps[i][j].to_bits(),
                    "abw ({i},{j})"
                );
                assert_eq!(
                    flat.lat_ms(i, j).to_bits(),
                    oracle.lat_ms[i][j].to_bits(),
                    "lat ({i},{j})"
                );
                assert_eq!(flat.hops(i, j), oracle.hops[i][j], "hops ({i},{j})");
            }
        }
    }
}
