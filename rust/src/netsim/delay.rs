//! The Eq. (3) delay model — the bridge from network to max-plus system.
//!
//! For an overlay arc (i → j):
//!
//! ```text
//! d_o(i,j) = s·T_c(i) + l(i,j) + M / min( C_UP(i)/|N_i⁻|,
//!                                         C_DN(j)/|N_j⁺|,
//!                                         A(i',j') )
//! ```
//!
//! with `d_o(i,i) = s·T_c(i)` (the computation-only self-loop). Silo i
//! uploads to its out-neighbours in parallel (uplink split |N_i⁻| ways);
//! downloads at j overlap (downlink split |N_j⁺| ways); the core contributes
//! the routed available bandwidth.
//!
//! The same object exposes the *designer-facing* connectivity-graph weights:
//! `d_c(i,j) = s·T_c(i) + l(i,j) + M/A(i',j')` for edge-capacitated designs
//! (Prop. 3.1 / 3.3) and the Alg.-1 node-capacitated undirected weight
//! `d_c⁽ᵘ⁾(i,j) = [s(T_c(i)+T_c(j)) + l(i,j)+l(j,i) + M/C_UP(i)+M/C_UP(j)]/2`.

use super::backend::BackendProfile;
use super::routing::{BwModel, Routes};
use super::underlay::Underlay;
use crate::fl::workloads::Workload;
use crate::graph::DiGraph;
use crate::maxplus::csr::CsrDelayDigraph;
use crate::maxplus::DelayDigraph;

/// Fully-instantiated delay model for one (network, workload, s, capacities)
/// configuration.
#[derive(Clone, Debug)]
pub struct DelayModel {
    pub n: usize,
    /// local computation steps per round.
    pub s: usize,
    /// model update size, bits.
    pub model_bits: f64,
    /// per-silo computation time for one local step, ms.
    pub tc_ms: Vec<f64>,
    /// per-silo access capacities, bit/s.
    pub cup_bps: Vec<f64>,
    pub cdn_bps: Vec<f64>,
    /// routed latency / available bandwidth.
    pub routes: Routes,
    /// how transmission time is priced ([`BackendProfile::scalar`] by
    /// default — the bit-identical pre-backend arithmetic).
    pub backend: BackendProfile,
}

impl DelayModel {
    /// Homogeneous setup: same access capacity everywhere, uniform core
    /// capacity, T_c from the workload. This is the Table-3 configuration.
    pub fn new(
        net: &Underlay,
        wl: &Workload,
        s: usize,
        access_bps: f64,
        core_bps: f64,
    ) -> DelayModel {
        let n = net.n_silos();
        DelayModel {
            n,
            s,
            model_bits: wl.model_bits,
            tc_ms: vec![wl.tc_ms; n],
            cup_bps: vec![access_bps; n],
            cdn_bps: vec![access_bps; n],
            // Static per-pair available bandwidths, Eq. (3) taken literally:
            // A(i',j') = min core capacity along the route, independent of
            // the overlay ("different messages do not interfere
            // significantly in the core network"). The fair-share variant
            // remains available for the Fig.-7 realism diagnostic and the
            // congestion ablation bench.
            routes: Routes::compute(net, core_bps, BwModel::MinCapacity),
            backend: BackendProfile::scalar(),
        }
    }

    /// Fully custom constructor (heterogeneous capacities — Fig. 3b).
    pub fn with_parts(
        s: usize,
        model_bits: f64,
        tc_ms: Vec<f64>,
        cup_bps: Vec<f64>,
        cdn_bps: Vec<f64>,
        routes: Routes,
    ) -> DelayModel {
        let n = tc_ms.len();
        assert_eq!(cup_bps.len(), n);
        assert_eq!(cdn_bps.len(), n);
        assert_eq!(routes.n(), n);
        DelayModel {
            n,
            s,
            model_bits,
            tc_ms,
            cup_bps,
            cdn_bps,
            routes,
            backend: BackendProfile::scalar(),
        }
    }

    /// Price transmissions with `backend` instead of the scalar default
    /// (builder style — `DelayModel::new(..).with_backend(..)`). Every
    /// weight this model produces (overlay arcs, designer weights, CSR
    /// reweighting, batched lanes) flows through the backend's
    /// [`BackendProfile::tx_ms`], so the whole pipeline becomes
    /// backend-conditional from this one knob.
    pub fn with_backend(mut self, backend: BackendProfile) -> DelayModel {
        self.backend = backend;
        self
    }

    /// Override one silo's access capacity (Fig. 3b: the STAR hub keeps a
    /// fast 10 Gbps link while everyone else is throttled).
    pub fn set_access(&mut self, silo: usize, up_bps: f64, dn_bps: f64) {
        self.cup_bps[silo] = up_bps;
        self.cdn_bps[silo] = dn_bps;
    }

    /// Computation-phase delay: `s · T_c(i)` (the self-loop weight).
    pub fn compute_ms(&self, i: usize) -> f64 {
        self.s as f64 * self.tc_ms[i]
    }

    /// Transmission milliseconds for `bits` at `rate_bps`, priced by the
    /// model's [`BackendProfile`]. With the default scalar backend this is
    /// the literal pre-backend expression
    /// (`if rate.is_infinite() { 0 } else { bits / rate * 1e3 }`).
    #[inline]
    fn tx_ms(&self, bits: f64, rate_bps: f64) -> f64 {
        self.backend.tx_ms(bits, rate_bps)
    }

    /// The overlay arc delay `d_o(i, j)` given the overlay degrees of the
    /// endpoints (Eq. 3).
    pub fn d_o(&self, i: usize, j: usize, out_deg_i: usize, in_deg_j: usize) -> f64 {
        assert!(out_deg_i >= 1 && in_deg_j >= 1, "degrees count this arc");
        let rate = (self.cup_bps[i] / out_deg_i as f64)
            .min(self.cdn_bps[j] / in_deg_j as f64)
            .min(self.routes.abw_bps(i, j));
        self.compute_ms(i) + self.routes.lat_ms(i, j) + self.tx_ms(self.model_bits, rate)
    }

    /// Eq.-(3) arc delay under a scenario perturbation (see
    /// [`super::scenario`]): the silo's computation time is scaled by
    /// `compute_mult`, the endpoint access capacities by `acc_mult_i` /
    /// `acc_mult_j`, and the routed core bandwidth by `core_mult`. With all
    /// multipliers at `1.0` this is **bit-identical** to [`DelayModel::d_o`]
    /// (each scale is an exact IEEE no-op), which is what pins the dynamic
    /// simulator to the static one under the identity scenario.
    pub fn d_o_perturbed(
        &self,
        i: usize,
        j: usize,
        out_deg_i: usize,
        in_deg_j: usize,
        compute_mult: f64,
        acc_mult_i: f64,
        acc_mult_j: f64,
        core_mult: f64,
    ) -> f64 {
        assert!(out_deg_i >= 1 && in_deg_j >= 1, "degrees count this arc");
        let rate = ((acc_mult_i * self.cup_bps[i]) / out_deg_i as f64)
            .min((acc_mult_j * self.cdn_bps[j]) / in_deg_j as f64)
            .min(core_mult * self.routes.abw_bps(i, j));
        compute_mult * self.compute_ms(i)
            + self.routes.lat_ms(i, j)
            + self.tx_ms(self.model_bits, rate)
    }

    /// Connectivity-graph delay `d_c(i,j) = s·T_c(i) + l(i,j) + M/A(i',j')`
    /// (Sect. 3.1) — the designer weight on edge-capacitated networks, and
    /// the cost Christofides' ring minimizes.
    pub fn d_c(&self, i: usize, j: usize) -> f64 {
        self.compute_ms(i)
            + self.routes.lat_ms(i, j)
            + self.tx_ms(self.model_bits, self.routes.abw_bps(i, j))
    }

    /// Prop.-3.1 undirected weight: mean of `d_c` in the two directions.
    pub fn edge_cap_undirected_weight(&self, i: usize, j: usize) -> f64 {
        0.5 * (self.d_c(i, j) + self.d_c(j, i))
    }

    /// Alg.-1 (lines 2-4) node-capacitated undirected weight:
    /// `[s(T_c(i)+T_c(j)) + l(i,j)+l(j,i) + M/C_UP(i)+M/C_UP(j)] / 2`.
    /// Both transmission terms are *uplink* terms — the symmetrized weight
    /// charges each endpoint's upload, per the Alg.-1 formula (the j-term
    /// erroneously folded in C_DN(j) before PR 7; the heterogeneous-access
    /// unit test pins the corrected form).
    pub fn node_cap_undirected_weight(&self, i: usize, j: usize) -> f64 {
        0.5 * (self.compute_ms(i)
            + self.compute_ms(j)
            + self.routes.lat_ms(i, j)
            + self.routes.lat_ms(j, i)
            + self.tx_ms(self.model_bits, self.cup_bps[i])
            + self.tx_ms(self.model_bits, self.cup_bps[j]))
    }

    /// Prop.-3.6 ring-designer weight on node-capacitated networks:
    /// `d'(i,j) = s·T_c(i) + l(i,j) + M/min(C_UP(i), C_DN(j), A(i',j'))` —
    /// the arc delay a degree-1 ring node would see.
    pub fn ring_weight(&self, i: usize, j: usize) -> f64 {
        let rate = self.cup_bps[i]
            .min(self.cdn_bps[j])
            .min(self.routes.abw_bps(i, j));
        self.compute_ms(i) + self.routes.lat_ms(i, j) + self.tx_ms(self.model_bits, rate)
    }

    /// Is the network effectively edge-capacitated for this configuration?
    /// (Sect. 3.1: `min(C_UP(i), C_DN(j))/N ≥ A(i',j')` for all pairs.)
    pub fn is_edge_capacitated(&self) -> bool {
        for i in 0..self.n {
            for j in 0..self.n {
                if i == j {
                    continue;
                }
                let lhs = self.cup_bps[i].min(self.cdn_bps[j]) / self.n as f64;
                if lhs < self.routes.abw_bps(i, j) {
                    return false;
                }
            }
        }
        true
    }

    /// Eq.-(3) delays for every arc of a round's communication graph, with
    /// access links split across the overlay degrees and the static routed
    /// available bandwidth A(i',j'). Returns `(i, j, d_o(i,j))` triples.
    pub fn arc_delays(&self, overlay: &DiGraph) -> Vec<(usize, usize, f64)> {
        assert_eq!(overlay.n(), self.n);
        overlay
            .edges()
            .iter()
            .map(|&(i, j, _)| {
                let out_deg = overlay.out_degree(i).max(1);
                let in_deg = overlay.in_degree(j).max(1);
                (i, j, self.d_o(i, j, out_deg, in_deg))
            })
            .collect()
    }

    /// Alternative delay evaluation where the round's concurrent flows also
    /// share core links (per-link capacity split across the flows routed
    /// over it). Not the paper's model — Eq. (3) keeps A(i',j') static —
    /// but exposed for the congestion ablation bench.
    pub fn arc_delays_congested(&self, overlay: &DiGraph) -> Vec<(usize, usize, f64)> {
        assert_eq!(overlay.n(), self.n);
        let flows: Vec<(usize, usize)> =
            overlay.edges().iter().map(|&(i, j, _)| (i, j)).collect();
        let loaded = self.routes.concurrent_abw(&flows);
        flows
            .iter()
            .zip(&loaded)
            .map(|(&(i, j), &a_loaded)| {
                let a = if !self.routes.has_paths() || self.routes.path(i, j).is_empty() {
                    self.routes.abw_bps(i, j)
                } else {
                    a_loaded
                };
                let out_deg = overlay.out_degree(i).max(1);
                let in_deg = overlay.in_degree(j).max(1);
                let rate = (self.cup_bps[i] / out_deg as f64)
                    .min(self.cdn_bps[j] / in_deg as f64)
                    .min(a);
                let d = self.compute_ms(i)
                    + self.routes.lat_ms(i, j)
                    + self.tx_ms(self.model_bits, rate);
                (i, j, d)
            })
            .collect()
    }

    /// Cycle time of the *non-pipelined* server-client round (FedAvg): the
    /// hub must receive every update before broadcasting, so one round is
    /// `s·T_c + max_i(uplink phase) + max_i(downlink phase)`. In the slow
    /// homogeneous regime this reduces to App. B's `τ_STAR = 2N·M/C`.
    /// (Eq. (5) applied to the star digraph would instead describe a
    /// *pipelined* hub that computes concurrently — not what FedAvg does.)
    pub fn star_cycle_time_ms(&self, hub: usize) -> f64 {
        let n = self.n;
        let fan = (n - 1).max(1) as f64;
        let mut up: f64 = 0.0;
        let mut dn: f64 = 0.0;
        for i in 0..n {
            if i == hub {
                continue;
            }
            let r_up = self.cup_bps[i]
                .min(self.cdn_bps[hub] / fan)
                .min(self.routes.abw_bps(i, hub));
            up = up.max(self.routes.lat_ms(i, hub) + self.tx_ms(self.model_bits, r_up));
            let r_dn = (self.cup_bps[hub] / fan)
                .min(self.cdn_bps[i])
                .min(self.routes.abw_bps(hub, i));
            dn = dn.max(self.routes.lat_ms(hub, i) + self.tx_ms(self.model_bits, r_dn));
        }
        let compute = (0..n)
            .filter(|&i| i != hub)
            .map(|i| self.compute_ms(i))
            .fold(0.0f64, f64::max);
        compute + up + dn
    }

    /// Materialize the max-plus delay digraph of an overlay: one arc per
    /// overlay edge with congestion-aware Eq.-(3) weights, plus the
    /// `s·T_c(i)` self-loops.
    pub fn delay_digraph(&self, overlay: &DiGraph) -> DelayDigraph {
        let mut g = DelayDigraph::new(self.n);
        for i in 0..self.n {
            g.arc(i, i, self.compute_ms(i));
        }
        for (i, j, d) in self.arc_delays(overlay) {
            g.arc(i, j, d);
        }
        g
    }

    /// The reusable CSR form of [`DelayModel::delay_digraph`]: the same
    /// arcs (base, unperturbed weights) flattened by destination, plus the
    /// overlay's fixed per-node degrees — everything a
    /// [`crate::netsim::scenario::RoundState`] needs to rewrite the weights
    /// in place each round ([`RoundState::reweight`]) with zero allocation.
    /// Built once per design; only a re-design rebuilds the structure.
    ///
    /// [`RoundState::reweight`]: crate::netsim::scenario::RoundState::reweight
    pub fn delay_csr(&self, overlay: &DiGraph) -> OverlayDelayCsr {
        assert_eq!(overlay.n(), self.n);
        let csr = CsrDelayDigraph::from_delay_digraph(&self.delay_digraph(overlay));
        OverlayDelayCsr {
            csr,
            out_deg: (0..self.n).map(|i| overlay.out_degree(i) as u32).collect(),
            in_deg: (0..self.n).map(|i| overlay.in_degree(i) as u32).collect(),
        }
    }

    /// Cycle time (ms) of a static overlay under this delay model (Eq. 5).
    pub fn cycle_time_ms(&self, overlay: &DiGraph) -> f64 {
        self.delay_digraph(overlay).cycle_time()
    }
}

/// A designed overlay's delay digraph in reusable CSR form, bundled with
/// the overlay degrees its Eq.-(3) weights depend on. The structure is
/// fixed between re-designs; scenarios mutate only the weight array
/// (`csr.for_each_arc_mut` via `RoundState::reweight`), which is what makes
/// the per-round stepping of `Timeline::simulate_reweighted`,
/// `DynamicTimeline::step_csr`, and the training engine allocation-free.
#[derive(Clone, Debug)]
pub struct OverlayDelayCsr {
    /// In-adjacency CSR of the overlay's delay digraph (self-loops + arcs).
    pub csr: CsrDelayDigraph,
    /// Overlay out-degrees |N_i⁻| (uplink split).
    pub out_deg: Vec<u32>,
    /// Overlay in-degrees |N_j⁺| (downlink split).
    pub in_deg: Vec<u32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::underlay::Underlay;

    fn gaia_model() -> DelayModel {
        let net = Underlay::builtin("gaia").unwrap();
        DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9)
    }

    #[test]
    fn self_loop_is_compute_only() {
        let m = gaia_model();
        assert!((m.compute_ms(0) - 25.4).abs() < 1e-9);
    }

    #[test]
    fn d_o_monotone_in_degree() {
        let m = gaia_model();
        let base = m.d_o(0, 1, 1, 1);
        assert!(m.d_o(0, 1, 4, 1) >= base);
        assert!(m.d_o(0, 1, 1, 8) >= base);
        assert!(m.d_o(0, 1, 16, 16) > base);
    }

    #[test]
    fn d_o_components_add_up() {
        let m = gaia_model();
        // degree 1 both sides: rate = min(10G, 10G, A=1G) = 1G
        // tx = 42.88e6 bits / 1e9 bps * 1e3 = 42.88 ms
        let d = m.d_o(0, 1, 1, 1);
        let expect = 25.4 + m.routes.lat_ms(0, 1) + 42.88;
        assert!((d - expect).abs() < 1e-9, "d={d} expect={expect}");
    }

    #[test]
    fn slow_access_dominates() {
        let net = Underlay::builtin("gaia").unwrap();
        let m = DelayModel::new(&net, &Workload::inaturalist(), 1, 100e6, 1e9);
        // rate = min(100M/1, 100M/1, 1G) = 100 Mbps → tx = 428.8 ms
        let d = m.d_o(0, 1, 1, 1);
        let expect = 25.4 + m.routes.lat_ms(0, 1) + 428.8;
        assert!((d - expect).abs() < 1e-6);
        assert!(!m.is_edge_capacitated());
    }

    #[test]
    fn edge_capacitated_detection() {
        let net = Underlay::builtin("gaia").unwrap();
        // access 100 Gbps vs core 1 Gbps, N=11 → 100G/11 = 9.1G ≥ 1G ✓
        let m = DelayModel::new(&net, &Workload::inaturalist(), 1, 100e9, 1e9);
        assert!(m.is_edge_capacitated());
    }

    #[test]
    fn s_scales_compute() {
        let net = Underlay::builtin("gaia").unwrap();
        let m1 = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let m5 = DelayModel::new(&net, &Workload::inaturalist(), 5, 10e9, 1e9);
        assert!((m5.compute_ms(0) - 5.0 * m1.compute_ms(0)).abs() < 1e-9);
        assert!((m5.d_o(0, 1, 1, 1) - m1.d_o(0, 1, 1, 1) - 4.0 * 25.4).abs() < 1e-9);
    }

    #[test]
    fn ring_cycle_time_matches_hand_computation() {
        let m = gaia_model();
        // build the identity ring 0→1→…→10→0
        let mut ring = DiGraph::new(11);
        for i in 0..11 {
            ring.add_edge(i, (i + 1) % 11, 0.0);
        }
        let tau = m.cycle_time_ms(&ring);
        // hand: mean over ring arcs of d_o with degrees 1;
        // compare with self-loop max too
        let mut total = 0.0;
        for i in 0..11 {
            total += m.d_o(i, (i + 1) % 11, 1, 1);
        }
        let ring_mean = total / 11.0;
        let max_self = (0..11).map(|i| m.compute_ms(i)).fold(0.0f64, f64::max);
        let expect = ring_mean.max(max_self);
        assert!((tau - expect).abs() < 1e-9, "τ={tau} expect={expect}");
    }

    #[test]
    fn heterogeneous_access_override() {
        let net = Underlay::builtin("gaia").unwrap();
        let mut m = DelayModel::new(&net, &Workload::inaturalist(), 1, 100e6, 1e9);
        m.set_access(0, 10e9, 10e9);
        // silo 0's uplink no longer the constraint; silo 1's downlink is
        let d01 = m.d_o(0, 1, 1, 1);
        let d10 = m.d_o(1, 0, 1, 1);
        assert!(d10 > d01 - 1e-9, "uplink of 1 still slow");
    }

    #[test]
    fn infinite_bandwidth_means_zero_tx() {
        assert_eq!(gaia_model().tx_ms(1e9, f64::INFINITY), 0.0);
    }

    #[test]
    fn node_cap_weight_charges_uplinks_only() {
        // Satellite-1 pin: on a heterogeneous-access model where
        // C_DN(j) < C_UP(j), the Alg.-1 j-term must be M/C_UP(j) — the
        // pre-PR-7 code folded in the downlink (min(C_DN, C_UP)) and the
        // two formulas differ exactly by that term.
        let mut m = gaia_model();
        m.set_access(1, 1e9, 1e8); // uplink 1 Gbps, downlink 100 Mbps
        let w = m.node_cap_undirected_weight(0, 1);
        let expect = 0.5
            * (m.compute_ms(0)
                + m.compute_ms(1)
                + m.routes.lat_ms(0, 1)
                + m.routes.lat_ms(1, 0)
                + m.model_bits / 10e9 * 1e3   // M/C_UP(0)
                + m.model_bits / 1e9 * 1e3); // M/C_UP(1), NOT the 1e8 downlink
        assert!((w - expect).abs() < 1e-9, "w={w} expect={expect}");
        let buggy = 0.5
            * (m.compute_ms(0)
                + m.compute_ms(1)
                + m.routes.lat_ms(0, 1)
                + m.routes.lat_ms(1, 0)
                + m.model_bits / 10e9 * 1e3
                + m.model_bits / 1e8 * 1e3);
        assert!(
            (w - buggy).abs() > 1.0,
            "pin must distinguish the corrected formula from the old one"
        );
        // Homogeneous access (every pre-existing designer test): the two
        // formulas coincide, so this fix changes nothing there.
        let h = gaia_model();
        let w_h = h.node_cap_undirected_weight(0, 1);
        let old_h = 0.5
            * (h.compute_ms(0)
                + h.compute_ms(1)
                + h.routes.lat_ms(0, 1)
                + h.routes.lat_ms(1, 0)
                + h.model_bits / 10e9 * 1e3
                + h.model_bits / 10e9 * 1e3);
        assert!((w_h - old_h).abs() < 1e-12);
    }

    #[test]
    fn delay_csr_matches_delay_digraph_bitwise() {
        let m = gaia_model();
        let mut ring = DiGraph::new(11);
        for i in 0..11 {
            ring.add_edge(i, (i + 1) % 11, 0.0);
        }
        let dd = m.delay_digraph(&ring);
        let ov = m.delay_csr(&ring);
        assert_eq!(ov.csr.n(), 11);
        assert_eq!(ov.csr.arcs(), dd.arcs.len());
        for i in 0..11 {
            assert_eq!(ov.out_deg[i], 1);
            assert_eq!(ov.in_deg[i], 1);
        }
        let norm = |arcs: &[(usize, usize, f64)]| {
            let mut v: Vec<(usize, usize, u64)> =
                arcs.iter().map(|&(s, d, w)| (s, d, w.to_bits())).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(norm(&ov.csr.to_delay_digraph().arcs), norm(&dd.arcs));
    }

    #[test]
    fn d_o_perturbed_identity_is_bit_identical() {
        let m = gaia_model();
        for (i, j) in [(0, 1), (3, 7), (10, 2)] {
            for (od, id) in [(1, 1), (3, 2), (10, 10)] {
                let plain = m.d_o(i, j, od, id);
                let pert = m.d_o_perturbed(i, j, od, id, 1.0, 1.0, 1.0, 1.0);
                assert_eq!(plain.to_bits(), pert.to_bits(), "({i},{j}) deg ({od},{id})");
            }
        }
    }

    #[test]
    fn d_o_perturbed_multipliers_move_the_right_terms() {
        let m = gaia_model();
        // 10× compute: the compute term scales, the rest doesn't.
        let d = m.d_o_perturbed(0, 1, 1, 1, 10.0, 1.0, 1.0, 1.0);
        assert!((d - (10.0 * 25.4 + m.routes.lat_ms(0, 1) + 42.88)).abs() < 1e-9);
        // Access ÷10 at degree 1 with a 1 Gbps core: access 1 Gbps is still
        // not the bottleneck, so the delay is unchanged.
        let d = m.d_o_perturbed(0, 1, 1, 1, 1.0, 0.1, 0.1, 1.0);
        assert!((d - m.d_o(0, 1, 1, 1)).abs() < 1e-9);
        // Core ÷10: the transmission term grows 10×.
        let d = m.d_o_perturbed(0, 1, 1, 1, 1.0, 1.0, 1.0, 0.1);
        assert!((d - (25.4 + m.routes.lat_ms(0, 1) + 428.8)).abs() < 1e-6);
    }

    #[test]
    fn explicit_scalar_backend_is_bit_identical_to_default() {
        use crate::netsim::backend::BackendProfile;
        let base = gaia_model();
        let scalar = gaia_model().with_backend(BackendProfile::by_name("backend:scalar").unwrap());
        for (i, j) in [(0, 1), (3, 7), (10, 2)] {
            assert_eq!(base.d_o(i, j, 2, 3).to_bits(), scalar.d_o(i, j, 2, 3).to_bits());
            assert_eq!(base.d_c(i, j).to_bits(), scalar.d_c(i, j).to_bits());
            assert_eq!(
                base.node_cap_undirected_weight(i, j).to_bits(),
                scalar.node_cap_undirected_weight(i, j).to_bits()
            );
            assert_eq!(base.ring_weight(i, j).to_bits(), scalar.ring_weight(i, j).to_bits());
        }
        assert_eq!(
            base.star_cycle_time_ms(0).to_bits(),
            scalar.star_cycle_time_ms(0).to_bits()
        );
    }

    #[test]
    fn message_backend_shifts_every_weight_by_the_message_term() {
        use crate::netsim::backend::BackendProfile;
        let base = gaia_model();
        let grpc = BackendProfile::by_name("backend:grpc").unwrap();
        let m = gaia_model().with_backend(grpc.clone());
        // iNaturalist = 42.88e6 bits over 4 MiB chunks → 2 messages; the
        // message term is rate-independent, so every weight shifts by the
        // same constant.
        let shift = grpc.tx_ms(base.model_bits, f64::INFINITY);
        assert!(shift > 0.0);
        for (i, j) in [(0, 1), (5, 9)] {
            assert!((m.d_o(i, j, 1, 1) - base.d_o(i, j, 1, 1) - shift).abs() < 1e-9);
            assert!((m.d_c(i, j) - base.d_c(i, j) - shift).abs() < 1e-9);
            assert!((m.ring_weight(i, j) - base.ring_weight(i, j) - shift).abs() < 1e-9);
        }
        // and the cycle time of a fixed overlay moves with it
        let mut ring = DiGraph::new(11);
        for i in 0..11 {
            ring.add_edge(i, (i + 1) % 11, 0.0);
        }
        assert!(m.cycle_time_ms(&ring) > base.cycle_time_ms(&ring));
    }
}
