//! Algorithm 3 — wall-clock reconstruction for an overlay.
//!
//! Thin façade over [`crate::maxplus::recurrence::Timeline`] that goes from
//! (overlay, delay model) straight to event times, used by the Fig. 2
//! experiments to convert loss-per-round into loss-per-wall-clock-ms.

use super::delay::DelayModel;
use crate::graph::DiGraph;
use crate::maxplus::recurrence::Timeline;

/// Wall-clock event times for `rounds` rounds of an overlay.
pub fn simulate(model: &DelayModel, overlay: &DiGraph, rounds: usize) -> Timeline {
    Timeline::simulate(&model.delay_digraph(overlay), rounds)
}

/// Time (ms) at which round `k` has completed at every silo.
pub fn round_completion_ms(model: &DelayModel, overlay: &DiGraph, rounds: usize) -> Vec<f64> {
    let tl = simulate(model, overlay, rounds);
    (0..=rounds).map(|k| tl.round_completion(k)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::workloads::Workload;
    use crate::netsim::underlay::Underlay;

    /// The identity ring 0→1→…→(n−1)→0 over the whole underlay, whatever
    /// its size (the old hand-rolled 11-node ring silently assumed gaia's).
    fn identity_ring(n: usize) -> DiGraph {
        let mut ring = DiGraph::new(n);
        for i in 0..n {
            ring.add_edge(i, (i + 1) % n, 0.0);
        }
        ring
    }

    #[test]
    fn timeline_slope_matches_cycle_time() {
        let net = Underlay::builtin("gaia").unwrap();
        let n = net.n_silos();
        let m = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let ring = identity_ring(n);
        // Estimator error analysis, not a guessed tolerance: after the
        // transient the recurrence is periodic with period dividing n (the
        // critical circuit is the ring itself — its mean exceeds the s·T_c
        // self-loops). `cycle_time_estimate` spans K − K/2 rounds; with
        // K = 60n both window edges are multiples of n, so the periodic
        // ripple cancels exactly and only the geometrically decaying
        // transient term remains — comfortably within 0.5% of τ, versus the
        // old 1% at an unaligned K = 300.
        let rounds = 60 * n;
        let tl = simulate(&m, &ring, rounds);
        let tau = m.cycle_time_ms(&ring);
        assert!(
            (tl.cycle_time_estimate() - tau).abs() < 0.005 * tau,
            "slope {} vs τ {tau}",
            tl.cycle_time_estimate()
        );
    }

    #[test]
    fn completion_times_increasing() {
        let net = Underlay::builtin("gaia").unwrap();
        let n = net.n_silos();
        let m = DelayModel::new(&net, &Workload::femnist(), 1, 1e9, 1e9);
        let c = round_completion_ms(&m, &identity_ring(n), 50);
        assert_eq!(c.len(), 51);
        assert!(c.windows(2).all(|w| w[1] >= w[0]));
        assert_eq!(c[0], 0.0);
    }
}
