//! Algorithm 3 — wall-clock reconstruction for an overlay.
//!
//! Thin façade over [`crate::maxplus::recurrence::Timeline`] that goes from
//! (overlay, delay model) straight to event times, used by the Fig. 2
//! experiments to convert loss-per-round into loss-per-wall-clock-ms —
//! plus [`DynamicTimeline`], the *incremental* form of the same recurrence
//! that the training engine ([`crate::fl::trainsim`]) and the adaptive
//! re-design loop ([`crate::topology::adaptive`]) drive round by round,
//! interleaved with work that depends on each round's completion time.

use super::delay::DelayModel;
use crate::graph::DiGraph;
use crate::maxplus::csr::CsrDelayDigraph;
use crate::maxplus::recurrence::{self, Timeline};
use crate::maxplus::DelayDigraph;

/// Wall-clock event times for `rounds` rounds of an overlay.
pub fn simulate(model: &DelayModel, overlay: &DiGraph, rounds: usize) -> Timeline {
    Timeline::simulate(&model.delay_digraph(overlay), rounds)
}

/// Time (ms) at which round `k` has completed at every silo.
pub fn round_completion_ms(model: &DelayModel, overlay: &DiGraph, rounds: usize) -> Vec<f64> {
    let tl = simulate(model, overlay, rounds);
    (0..=rounds).map(|k| tl.round_completion(k)).collect()
}

/// Incremental Eq.-(4) stepper: one recurrence step per call, over a
/// per-round delay digraph the caller supplies (re-weighted in place under
/// a scenario, swapped wholesale on an adaptive re-design).
///
/// Fed the same per-round delays, the trajectory is bit-identical to
/// [`Timeline::simulate`] / [`Timeline::simulate_dynamic`] /
/// [`Timeline::simulate_reweighted`] — same kernel, same fold (pinned by
/// `tests/dynamic.rs` and `tests/train.rs`). The incremental shape exists
/// so callers can *interleave* the recurrence with per-round work that
/// reads completion times as they materialize: the throughput monitor and
/// the wall-clock stamps on training evals.
///
/// Zero-allocation contract (PR 5): event times live in a double buffer
/// ([`recurrence::step_csr_into`] writes into the spare, then the buffers
/// swap), so [`DynamicTimeline::step_csr`] performs no heap allocation;
/// with [`DynamicTimeline::with_capacity`] the completion series is
/// pre-reserved too, and a whole warm simulation round allocates nothing —
/// gated by the counting allocator in `benches/memory.rs`.
#[derive(Clone, Debug)]
pub struct DynamicTimeline {
    t: Vec<f64>,
    /// spare buffer for the double-buffered step.
    next: Vec<f64>,
    completion_ms: Vec<f64>,
}

impl DynamicTimeline {
    /// Start at `t_i(0) = 0` for `n` silos; round 0 completes at 0 ms.
    pub fn new(n: usize) -> DynamicTimeline {
        DynamicTimeline {
            t: vec![0.0f64; n],
            next: vec![0.0f64; n],
            completion_ms: vec![0.0],
        }
    }

    /// Like [`DynamicTimeline::new`], with the completion series
    /// pre-reserved for `rounds` rounds (so a known-horizon loop never
    /// reallocates it).
    pub fn with_capacity(n: usize, rounds: usize) -> DynamicTimeline {
        let mut tl = DynamicTimeline::new(n);
        tl.completion_ms.reserve(rounds);
        tl
    }

    /// Advance one round over this round's delay digraph (dense oracle
    /// form — materializes the nested in-adjacency); returns the round's
    /// completion time `max_i t_i` (ms). Hot paths use
    /// [`DynamicTimeline::step_csr`].
    pub fn step(&mut self, dd: &DelayDigraph) -> f64 {
        assert_eq!(dd.n, self.t.len(), "round digraph changed size");
        recurrence::step_into(&self.t, &dd.in_arcs(), &mut self.next);
        self.finish_round()
    }

    /// Advance one round over a CSR delay digraph — the zero-allocation
    /// form ([`recurrence::step_csr_auto_into`] into the spare buffer, then
    /// swap). Bit-identical to [`DynamicTimeline::step`] on equal weights;
    /// large cells row-partition across the intra-cell pool (PR 10), which
    /// is a perf switch only — the trajectory is bit-identical for any
    /// worker count.
    pub fn step_csr(&mut self, g: &CsrDelayDigraph) -> f64 {
        assert_eq!(g.n(), self.t.len(), "round digraph changed size");
        recurrence::step_csr_auto_into(&self.t, g, &mut self.next);
        self.finish_round()
    }

    fn finish_round(&mut self) -> f64 {
        std::mem::swap(&mut self.t, &mut self.next);
        let done = self.t.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        self.completion_ms.push(done);
        done
    }

    /// Rounds simulated so far.
    pub fn rounds(&self) -> usize {
        self.completion_ms.len() - 1
    }

    /// Completion time (ms) of every round simulated so far; `[0] = 0`.
    pub fn completion_ms(&self) -> &[f64] {
        &self.completion_ms
    }

    /// Completion time of the most recent round.
    pub fn last_completion_ms(&self) -> f64 {
        *self.completion_ms.last().expect("round 0 always present")
    }

    /// Consume the stepper, keeping the completion series.
    pub fn into_completion_ms(self) -> Vec<f64> {
        self.completion_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::workloads::Workload;
    use crate::netsim::underlay::Underlay;

    /// The identity ring 0→1→…→(n−1)→0 over the whole underlay, whatever
    /// its size (the old hand-rolled 11-node ring silently assumed gaia's).
    fn identity_ring(n: usize) -> DiGraph {
        let mut ring = DiGraph::new(n);
        for i in 0..n {
            ring.add_edge(i, (i + 1) % n, 0.0);
        }
        ring
    }

    #[test]
    fn timeline_slope_matches_cycle_time() {
        let net = Underlay::builtin("gaia").unwrap();
        let n = net.n_silos();
        let m = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let ring = identity_ring(n);
        // Estimator error analysis, not a guessed tolerance: after the
        // transient the recurrence is periodic with period dividing n (the
        // critical circuit is the ring itself — its mean exceeds the s·T_c
        // self-loops). `cycle_time_estimate` spans K − K/2 rounds; with
        // K = 60n both window edges are multiples of n, so the periodic
        // ripple cancels exactly and only the geometrically decaying
        // transient term remains — comfortably within 0.5% of τ, versus the
        // old 1% at an unaligned K = 300.
        let rounds = 60 * n;
        let tl = simulate(&m, &ring, rounds);
        let tau = m.cycle_time_ms(&ring);
        assert!(
            (tl.cycle_time_estimate() - tau).abs() < 0.005 * tau,
            "slope {} vs τ {tau}",
            tl.cycle_time_estimate()
        );
    }

    #[test]
    fn dynamic_timeline_matches_batch_simulate_bit_for_bit() {
        let net = Underlay::builtin("gaia").unwrap();
        let n = net.n_silos();
        let m = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let ring = identity_ring(n);
        let dd = m.delay_digraph(&ring);
        let batch = Timeline::simulate(&dd, 80);
        let mut inc = DynamicTimeline::new(n);
        for k in 0..80 {
            let done = inc.step(&dd);
            assert_eq!(
                done.to_bits(),
                batch.round_completion(k + 1).to_bits(),
                "round {k}"
            );
        }
        assert_eq!(inc.rounds(), 80);
        assert_eq!(inc.completion_ms().len(), 81);
        assert_eq!(inc.last_completion_ms(), batch.round_completion(80));
        let series = inc.into_completion_ms();
        for (k, c) in series.iter().enumerate() {
            assert_eq!(c.to_bits(), batch.round_completion(k).to_bits(), "k={k}");
        }
    }

    #[test]
    fn step_csr_matches_step_bit_for_bit() {
        let net = Underlay::builtin("gaia").unwrap();
        let n = net.n_silos();
        let m = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let ring = identity_ring(n);
        let dd = m.delay_digraph(&ring);
        let csr = CsrDelayDigraph::from_delay_digraph(&dd);
        let mut dense = DynamicTimeline::new(n);
        let mut flat = DynamicTimeline::with_capacity(n, 60);
        for k in 0..60 {
            let a = dense.step(&dd);
            let b = flat.step_csr(&csr);
            assert_eq!(a.to_bits(), b.to_bits(), "round {k}");
        }
        assert_eq!(flat.rounds(), 60);
    }

    #[test]
    fn completion_times_increasing() {
        let net = Underlay::builtin("gaia").unwrap();
        let n = net.n_silos();
        let m = DelayModel::new(&net, &Workload::femnist(), 1, 1e9, 1e9);
        let c = round_completion_ms(&m, &identity_ring(n), 50);
        assert_eq!(c.len(), 51);
        assert!(c.windows(2).all(|w| w[1] >= w[0]));
        assert_eq!(c[0], 0.0);
    }
}
