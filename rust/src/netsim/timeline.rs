//! Algorithm 3 — wall-clock reconstruction for an overlay.
//!
//! Thin façade over [`crate::maxplus::recurrence::Timeline`] that goes from
//! (overlay, delay model) straight to event times, used by the Fig. 2
//! experiments to convert loss-per-round into loss-per-wall-clock-ms.

use super::delay::DelayModel;
use crate::graph::DiGraph;
use crate::maxplus::recurrence::Timeline;

/// Wall-clock event times for `rounds` rounds of an overlay.
pub fn simulate(model: &DelayModel, overlay: &DiGraph, rounds: usize) -> Timeline {
    Timeline::simulate(&model.delay_digraph(overlay), rounds)
}

/// Time (ms) at which round `k` has completed at every silo.
pub fn round_completion_ms(model: &DelayModel, overlay: &DiGraph, rounds: usize) -> Vec<f64> {
    let tl = simulate(model, overlay, rounds);
    (0..=rounds).map(|k| tl.round_completion(k)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::workloads::Workload;
    use crate::netsim::underlay::Underlay;

    #[test]
    fn timeline_slope_matches_cycle_time() {
        let net = Underlay::builtin("gaia").unwrap();
        let m = DelayModel::new(&net, &Workload::inaturalist(), 1, 10e9, 1e9);
        let mut ring = DiGraph::new(11);
        for i in 0..11 {
            ring.add_edge(i, (i + 1) % 11, 0.0);
        }
        let tl = simulate(&m, &ring, 300);
        let tau = m.cycle_time_ms(&ring);
        assert!(
            (tl.cycle_time_estimate() - tau).abs() < 0.01 * tau,
            "slope {} vs τ {tau}",
            tl.cycle_time_estimate()
        );
    }

    #[test]
    fn completion_times_increasing() {
        let net = Underlay::builtin("gaia").unwrap();
        let m = DelayModel::new(&net, &Workload::femnist(), 1, 1e9, 1e9);
        let mut ring = DiGraph::new(11);
        for i in 0..11 {
            ring.add_edge(i, (i + 1) % 11, 0.0);
        }
        let c = round_completion_ms(&m, &ring, 50);
        assert_eq!(c.len(), 51);
        assert!(c.windows(2).all(|w| w[1] >= w[0]));
        assert_eq!(c[0], 0.0);
    }
}
