//! Message-level communication backends — the fifth named spec kind.
//!
//! Eq. (3) prices a transfer as pure wire time `M / rate`. Real cross-silo
//! stacks do not ship one opaque blob: gRPC chunks the update into
//! fixed-size messages and pays per-message framing/serialization overhead,
//! RDMA posts one large transfer with near-zero software cost, and
//! parameter-sharded trainers pipeline several messages in flight. A
//! [`BackendProfile`] captures that as
//!
//! ```text
//! tx(M, rate) = M / rate                      // wire time, unchanged
//!             + ceil(ceil(M / chunk) / pipe) · overhead_ms
//! ```
//!
//! i.e. the wire term is exactly the scalar model's, plus one `overhead_ms`
//! per *window* of `pipe` in-flight messages of `chunk` bits each. The
//! default profile, `backend:scalar`, skips the message term entirely and
//! evaluates the **bit-identical** pre-backend arithmetic, which is what
//! keeps every fixture, golden and determinism gate byte-stable.
//!
//! Profiles resolve through the [`crate::spec::Resolve`] registry like
//! every other named kind: `backend:grpc`, `rdma`, `grpc:chunk4M:pipe8`
//! (the `backend:` prefix is optional, modifiers compose left to right).

use crate::spec::{Resolve, ResolveError};
use anyhow::Result;

/// Default gRPC message size: 4 MiB chunks (the classic gRPC max-message
/// default), in bits.
const GRPC_CHUNK_BITS: f64 = 4.0 * 1024.0 * 1024.0 * 8.0;
/// Per-message gRPC overhead: HTTP/2 framing + protobuf (de)serialization.
const GRPC_OVERHEAD_MS: f64 = 0.25;
/// RDMA posts the whole update as one transfer with tiny software cost.
const RDMA_OVERHEAD_MS: f64 = 0.01;

/// How a backend turns bits-on-the-wire into milliseconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BackendKind {
    /// The pre-backend Eq.-(3) arithmetic, bit for bit.
    Scalar,
    /// Chunked, pipelined messaging with per-message overhead.
    Message {
        /// Software cost per message window, ms.
        overhead_ms: f64,
        /// Message payload size, bits (`f64::INFINITY` = single message).
        chunk_bits: f64,
        /// Messages in flight per overhead window (parameter shards).
        pipeline: u32,
    },
}

/// A named communication-backend profile; prices transmission time for the
/// delay model ([`crate::netsim::delay::DelayModel`] holds one).
#[derive(Clone, Debug, PartialEq)]
pub struct BackendProfile {
    name: String,
    kind: BackendKind,
}

impl BackendProfile {
    /// The default backend: scalar wire time, no message term. Pinned
    /// bit-identical to the pre-backend `DelayModel` arithmetic.
    pub fn scalar() -> BackendProfile {
        BackendProfile {
            name: "backend:scalar".to_string(),
            kind: BackendKind::Scalar,
        }
    }

    /// gRPC-style chunked messaging: 4 MiB messages, per-message overhead,
    /// no pipelining.
    pub fn grpc() -> BackendProfile {
        BackendProfile {
            name: "backend:grpc".to_string(),
            kind: BackendKind::Message {
                overhead_ms: GRPC_OVERHEAD_MS,
                chunk_bits: GRPC_CHUNK_BITS,
                pipeline: 1,
            },
        }
    }

    /// RDMA-style single-message transfer with near-zero software overhead.
    pub fn rdma() -> BackendProfile {
        BackendProfile {
            name: "backend:rdma".to_string(),
            kind: BackendKind::Message {
                overhead_ms: RDMA_OVERHEAD_MS,
                chunk_bits: f64::INFINITY,
                pipeline: 1,
            },
        }
    }

    /// Canonical name, `backend:` prefix included.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The pricing rule.
    pub fn kind(&self) -> BackendKind {
        self.kind
    }

    /// True for the default scalar backend (the byte-identity fast path).
    pub fn is_scalar(&self) -> bool {
        self.kind == BackendKind::Scalar
    }

    /// Resolve a backend spec — a thin delegate into the
    /// [`crate::spec::Resolve`] registry (pinned error format, suggestions).
    ///
    /// # Examples
    ///
    /// ```
    /// use fedtopo::netsim::backend::BackendProfile;
    ///
    /// // the default backend reproduces scalar Eq.-(3) wire time exactly
    /// let scalar = BackendProfile::by_name("backend:scalar").unwrap();
    /// assert_eq!(scalar.tx_ms(1e9, 1e9), 1e3);
    ///
    /// // modifiers compose; the 'backend:' prefix is optional
    /// let b = BackendProfile::by_name("grpc:chunk4M:pipe8").unwrap();
    /// assert_eq!(b.name(), "backend:grpc:chunk4M:pipe8");
    /// assert!(b.tx_ms(1e9, 1e9) > scalar.tx_ms(1e9, 1e9));
    ///
    /// // typos get the registry's uniform error with a suggestion
    /// let err = BackendProfile::by_name("grcp").unwrap_err().to_string();
    /// assert!(err.starts_with("cannot resolve backend 'grcp'"));
    /// assert!(err.ends_with("did you mean 'grpc'?"));
    /// ```
    pub fn by_name(name: &str) -> Result<BackendProfile> {
        <BackendProfile as Resolve>::resolve(name)
    }

    /// Transmission milliseconds for `bits` at `rate_bps`.
    ///
    /// The scalar arm is the literal pre-backend expression (`0.0` at
    /// infinite rate, else `bits / rate_bps * 1e3`). Message backends add
    /// `ceil(ceil(bits/chunk) / pipeline) · overhead_ms` on top of the same
    /// wire term; the overhead is software cost, so it is charged even at
    /// infinite wire rate.
    pub fn tx_ms(&self, bits: f64, rate_bps: f64) -> f64 {
        match self.kind {
            BackendKind::Scalar => {
                if rate_bps.is_infinite() {
                    0.0
                } else {
                    bits / rate_bps * 1e3
                }
            }
            BackendKind::Message {
                overhead_ms,
                chunk_bits,
                pipeline,
            } => {
                let wire = if rate_bps.is_infinite() {
                    0.0
                } else {
                    bits / rate_bps * 1e3
                };
                let msgs = (bits / chunk_bits).ceil().max(1.0);
                let windows = (msgs / pipeline as f64).ceil();
                wire + windows * overhead_ms
            }
        }
    }
}

impl Default for BackendProfile {
    fn default() -> BackendProfile {
        BackendProfile::scalar()
    }
}

/// True when a `--backends` axis is the implicit default — a single spec
/// resolving to the scalar backend. Reports keep their pre-backend shape
/// (no backend fields) exactly when this holds, which is what preserves
/// byte-identity of every existing invocation.
pub fn axis_is_default(backends: &[String]) -> bool {
    match backends {
        [one] => BackendProfile::by_name(one).map(|b| b.is_scalar()).unwrap_or(false),
        _ => false,
    }
}

impl Resolve for BackendProfile {
    const KIND: &'static str = "backend";

    fn names() -> Vec<&'static str> {
        vec!["scalar", "grpc", "rdma"]
    }

    fn grammar() -> String {
        "scalar | grpc | rdma, modifiers :chunk<bytes>[k|M|G], :over<ms>, \
         :pipe<depth> (e.g. grpc:chunk4M), optional 'backend:' prefix"
            .to_string()
    }

    fn parse_spec(input: &str) -> Result<BackendProfile, ResolveError> {
        let err = |reason: String| {
            ResolveError::new(Self::KIND, input, reason).expected(Self::grammar())
        };
        let bare = input.strip_prefix("backend:").unwrap_or(input);
        if bare.is_empty() {
            return Err(err("empty backend spec".to_string()));
        }
        let mut it = bare.split(':');
        let base = it.next().unwrap_or("");
        let mut prof = match base {
            "scalar" => BackendProfile::scalar(),
            "grpc" => BackendProfile::grpc(),
            "rdma" => BackendProfile::rdma(),
            other => {
                return Err(err(format!("unknown backend '{other}'"))
                    .suggest(other, &Self::names()))
            }
        };
        for m in it {
            apply_modifier(&mut prof.kind, m).map_err(err)?;
        }
        prof.name = format!("backend:{bare}");
        Ok(prof)
    }
}

/// Apply one `chunk<bytes>` / `over<ms>` / `pipe<depth>` modifier in place.
fn apply_modifier(kind: &mut BackendKind, m: &str) -> std::result::Result<(), String> {
    match kind {
        BackendKind::Scalar => Err("'scalar' takes no modifiers".to_string()),
        BackendKind::Message {
            overhead_ms,
            chunk_bits,
            pipeline,
        } => {
            if let Some(sz) = m.strip_prefix("chunk") {
                *chunk_bits = parse_chunk_bits(sz)?;
            } else if let Some(ms) = m.strip_prefix("over") {
                let v: f64 = ms.parse().map_err(|_| format!("bad overhead '{ms}'"))?;
                if !v.is_finite() || v < 0.0 {
                    return Err(format!("overhead '{ms}' must be a non-negative ms value"));
                }
                *overhead_ms = v;
            } else if let Some(d) = m.strip_prefix("pipe") {
                let v: u32 = d.parse().map_err(|_| format!("bad pipeline depth '{d}'"))?;
                if v == 0 {
                    return Err("pipeline depth must be ≥ 1".to_string());
                }
                *pipeline = v;
            } else {
                return Err(format!("unknown backend modifier '{m}'"));
            }
            Ok(())
        }
    }
}

/// `<bytes>` with an optional binary `k`/`M`/`G` suffix, returned in bits.
fn parse_chunk_bits(s: &str) -> std::result::Result<f64, String> {
    let (num, mult) = match s.as_bytes().last() {
        Some(b'k') => (&s[..s.len() - 1], 1024.0),
        Some(b'M') => (&s[..s.len() - 1], 1024.0 * 1024.0),
        Some(b'G') => (&s[..s.len() - 1], 1024.0 * 1024.0 * 1024.0),
        _ => (s, 1.0),
    };
    let v: u64 = num.parse().map_err(|_| format!("bad chunk size '{s}'"))?;
    if v == 0 {
        return Err("chunk size must be ≥ 1 byte".to_string());
    }
    Ok(v as f64 * mult * 8.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_the_literal_pre_backend_expression() {
        let b = BackendProfile::scalar();
        let bits = 42.88e6;
        for rate in [1e6, 1e9, 10e9, 123.456e6] {
            assert_eq!(b.tx_ms(bits, rate).to_bits(), (bits / rate * 1e3).to_bits());
        }
        assert_eq!(b.tx_ms(bits, f64::INFINITY), 0.0);
    }

    #[test]
    fn grpc_charges_one_overhead_per_chunk() {
        let b = BackendProfile::grpc();
        // 42.88e6 bits / (4 MiB · 8) bits = 1.278… → 2 messages
        let wire = 42.88e6 / 1e9 * 1e3;
        let got = b.tx_ms(42.88e6, 1e9);
        assert!((got - (wire + 2.0 * GRPC_OVERHEAD_MS)).abs() < 1e-12, "{got}");
        // overhead is software cost: charged even at infinite wire rate
        assert!((b.tx_ms(42.88e6, f64::INFINITY) - 2.0 * GRPC_OVERHEAD_MS).abs() < 1e-12);
    }

    #[test]
    fn rdma_is_one_message() {
        let b = BackendProfile::rdma();
        let wire = 161.06e6 / 1e9 * 1e3;
        assert!((b.tx_ms(161.06e6, 1e9) - (wire + RDMA_OVERHEAD_MS)).abs() < 1e-12);
    }

    #[test]
    fn pipelining_divides_the_overhead_windows() {
        let deep = BackendProfile::by_name("grpc:pipe8").unwrap();
        let flat = BackendProfile::grpc();
        // 100 MiB → 25 messages → 25 windows flat, ceil(25/8)=4 deep
        let bits = 100.0 * 1024.0 * 1024.0 * 8.0;
        let wire = bits / 1e9 * 1e3;
        assert!((flat.tx_ms(bits, 1e9) - (wire + 25.0 * GRPC_OVERHEAD_MS)).abs() < 1e-9);
        assert!((deep.tx_ms(bits, 1e9) - (wire + 4.0 * GRPC_OVERHEAD_MS)).abs() < 1e-9);
    }

    #[test]
    fn modifiers_parse_and_compose() {
        let b = BackendProfile::by_name("backend:grpc:chunk64k:over0.5:pipe4").unwrap();
        assert_eq!(b.name(), "backend:grpc:chunk64k:over0.5:pipe4");
        assert_eq!(
            b.kind(),
            BackendKind::Message {
                overhead_ms: 0.5,
                chunk_bits: 64.0 * 1024.0 * 8.0,
                pipeline: 4,
            }
        );
        let g = BackendProfile::by_name("rdma:chunk1G").unwrap();
        let BackendKind::Message { chunk_bits, .. } = g.kind() else {
            panic!("rdma is a message backend")
        };
        assert_eq!(chunk_bits, 1024.0 * 1024.0 * 1024.0 * 8.0);
    }

    #[test]
    fn axis_default_detection() {
        assert!(axis_is_default(&["backend:scalar".to_string()]));
        assert!(axis_is_default(&["scalar".to_string()]));
        assert!(!axis_is_default(&["backend:grpc".to_string()]));
        assert!(!axis_is_default(&[
            "backend:scalar".to_string(),
            "backend:grpc".to_string()
        ]));
        assert!(!axis_is_default(&["not-a-backend".to_string()]));
    }

    #[test]
    fn malformed_specs_error_with_the_registry_format() {
        for (input, needle) in [
            ("grcp", "unknown backend 'grcp'"),
            ("backend:", "empty backend spec"),
            ("scalar:chunk4M", "'scalar' takes no modifiers"),
            ("grpc:chunkXL", "bad chunk size 'XL'"),
            ("grpc:chunk0", "chunk size must be ≥ 1 byte"),
            ("grpc:overfast", "bad overhead 'fast'"),
            ("grpc:pipe0", "pipeline depth must be ≥ 1"),
            ("grpc:zip9", "unknown backend modifier 'zip9'"),
        ] {
            let msg = BackendProfile::by_name(input).unwrap_err().to_string();
            assert!(
                msg.starts_with(&format!("cannot resolve backend '{input}': {needle}")),
                "{input}: {msg}"
            );
            assert!(msg.contains("; expected "), "{input}: {msg}");
        }
    }
}
