//! Graph Modelling Language (GML) parser and writer.
//!
//! The paper's network simulator "takes as input an arbitrary underlay
//! topology described in the Graph Modelling Language [36]" (Sect. 4) — the
//! format used by The Internet Topology Zoo and Rocketfuel dumps. We support
//! the subset those files use: nested `key [ ... ]` records, `id`, `label`,
//! `Latitude`/`Longitude`, `source`/`target`, numeric and quoted values.
//! Real Topology Zoo files can be dropped in via `fedtopo ... --gml file`.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// A parsed GML value.
#[derive(Clone, Debug, PartialEq)]
pub enum GmlValue {
    Num(f64),
    Str(String),
    List(GmlList),
}

/// An ordered multimap — GML allows repeated keys (`node`, `edge`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GmlList(pub Vec<(String, GmlValue)>);

impl GmlList {
    pub fn get(&self, key: &str) -> Option<&GmlValue> {
        self.0
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(key))
            .map(|(_, v)| v)
    }
    pub fn all<'a>(&'a self, key: &'a str) -> impl Iterator<Item = &'a GmlValue> + 'a {
        self.0
            .iter()
            .filter(move |(k, _)| k.eq_ignore_ascii_case(key))
            .map(|(_, v)| v)
    }
    pub fn num(&self, key: &str) -> Option<f64> {
        match self.get(key) {
            Some(GmlValue::Num(n)) => Some(*n),
            _ => None,
        }
    }
    pub fn str(&self, key: &str) -> Option<&str> {
        match self.get(key) {
            Some(GmlValue::Str(s)) => Some(s),
            _ => None,
        }
    }
}

/// A GML node record (as used by topology files).
#[derive(Clone, Debug)]
pub struct GmlNode {
    pub id: i64,
    pub label: String,
    pub lat: Option<f64>,
    pub lon: Option<f64>,
}

/// A GML edge record.
#[derive(Clone, Debug)]
pub struct GmlEdge {
    pub source: i64,
    pub target: i64,
}

/// A parsed topology: nodes + edges from the top-level `graph [...]`.
#[derive(Clone, Debug)]
pub struct GmlGraph {
    pub nodes: Vec<GmlNode>,
    pub edges: Vec<GmlEdge>,
}

fn tokenize(src: &str) -> Result<Vec<String>> {
    let mut toks = Vec::new();
    let mut chars = src.chars().peekable();
    while let Some(&c) = chars.peek() {
        match c {
            ' ' | '\t' | '\n' | '\r' => {
                chars.next();
            }
            '#' => {
                // comment to end of line
                for c in chars.by_ref() {
                    if c == '\n' {
                        break;
                    }
                }
            }
            '[' | ']' => {
                toks.push(c.to_string());
                chars.next();
            }
            '"' => {
                chars.next();
                let mut s = String::from("\"");
                for c in chars.by_ref() {
                    if c == '"' {
                        break;
                    }
                    s.push(c);
                }
                toks.push(s);
            }
            _ => {
                let mut s = String::new();
                while let Some(&c) = chars.peek() {
                    if c.is_whitespace() || c == '[' || c == ']' {
                        break;
                    }
                    s.push(c);
                    chars.next();
                }
                if s.is_empty() {
                    bail!("tokenizer stuck at char {c:?}");
                }
                toks.push(s);
            }
        }
    }
    Ok(toks)
}

fn parse_list(toks: &[String], pos: &mut usize) -> Result<GmlList> {
    let mut list = GmlList::default();
    while *pos < toks.len() {
        let t = &toks[*pos];
        if t == "]" {
            *pos += 1;
            return Ok(list);
        }
        let key = t.clone();
        *pos += 1;
        let v = toks
            .get(*pos)
            .with_context(|| format!("key '{key}' without a value"))?;
        if v == "[" {
            *pos += 1;
            let inner = parse_list(toks, pos)?;
            list.0.push((key, GmlValue::List(inner)));
        } else if let Some(stripped) = v.strip_prefix('"') {
            list.0.push((key, GmlValue::Str(stripped.to_string())));
            *pos += 1;
        } else if let Ok(n) = v.parse::<f64>() {
            list.0.push((key, GmlValue::Num(n)));
            *pos += 1;
        } else {
            // GML allows bare words as values (e.g. `Creator foo`)
            list.0.push((key, GmlValue::Str(v.clone())));
            *pos += 1;
        }
    }
    Ok(list)
}

/// Parse a full GML document into its top-level key list.
pub fn parse(src: &str) -> Result<GmlList> {
    let toks = tokenize(src)?;
    let mut pos = 0;
    parse_list(&toks, &mut pos)
}

/// Parse and extract the `graph [...]` record as nodes + edges.
pub fn parse_graph(src: &str) -> Result<GmlGraph> {
    let top = parse(src)?;
    let graph = match top.get("graph") {
        Some(GmlValue::List(g)) => g,
        _ => bail!("no top-level 'graph [...]' record"),
    };
    let mut nodes = Vec::new();
    let mut edges = Vec::new();
    for v in graph.all("node") {
        let GmlValue::List(n) = v else {
            bail!("malformed node record")
        };
        let id = n.num("id").context("node without id")? as i64;
        let label = n
            .str("label")
            .map(|s| s.to_string())
            .unwrap_or_else(|| format!("node{id}"));
        nodes.push(GmlNode {
            id,
            label,
            lat: n.num("Latitude"),
            lon: n.num("Longitude"),
        });
    }
    for v in graph.all("edge") {
        let GmlValue::List(e) = v else {
            bail!("malformed edge record")
        };
        edges.push(GmlEdge {
            source: e.num("source").context("edge without source")? as i64,
            target: e.num("target").context("edge without target")? as i64,
        });
    }
    Ok(GmlGraph { nodes, edges })
}

/// Serialize nodes + edges back to GML (deterministic; round-trips through
/// [`parse_graph`]).
pub fn write_graph(g: &GmlGraph, name: &str) -> String {
    let mut out = String::new();
    out.push_str("graph [\n");
    out.push_str(&format!("  label \"{name}\"\n"));
    for n in &g.nodes {
        out.push_str("  node [\n");
        out.push_str(&format!("    id {}\n", n.id));
        out.push_str(&format!("    label \"{}\"\n", n.label));
        if let (Some(lat), Some(lon)) = (n.lat, n.lon) {
            out.push_str(&format!("    Latitude {lat}\n"));
            out.push_str(&format!("    Longitude {lon}\n"));
        }
        out.push_str("  ]\n");
    }
    for e in &g.edges {
        out.push_str("  edge [\n");
        out.push_str(&format!("    source {}\n", e.source));
        out.push_str(&format!("    target {}\n", e.target));
        out.push_str("  ]\n");
    }
    out.push_str("]\n");
    out
}

/// Index GML node ids (arbitrary integers) to dense 0..n indices.
pub fn dense_index(g: &GmlGraph) -> BTreeMap<i64, usize> {
    g.nodes
        .iter()
        .enumerate()
        .map(|(i, n)| (n.id, i))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# Topology Zoo style sample
graph [
  label "tiny"
  node [
    id 0
    label "Paris"
    Latitude 48.8566
    Longitude 2.3522
  ]
  node [
    id 1
    label "London"
    Latitude 51.5074
    Longitude -0.1278
  ]
  node [ id 5 label "NoGeo" ]
  edge [ source 0 target 1 ]
  edge [ source 1 target 5 ]
]
"#;

    #[test]
    fn parses_sample() {
        let g = parse_graph(SAMPLE).unwrap();
        assert_eq!(g.nodes.len(), 3);
        assert_eq!(g.edges.len(), 2);
        assert_eq!(g.nodes[0].label, "Paris");
        assert!((g.nodes[0].lat.unwrap() - 48.8566).abs() < 1e-9);
        assert!(g.nodes[2].lat.is_none());
        assert_eq!(g.edges[1].source, 1);
        assert_eq!(g.edges[1].target, 5);
    }

    #[test]
    fn dense_index_maps_sparse_ids() {
        let g = parse_graph(SAMPLE).unwrap();
        let idx = dense_index(&g);
        assert_eq!(idx[&0], 0);
        assert_eq!(idx[&1], 1);
        assert_eq!(idx[&5], 2);
    }

    #[test]
    fn roundtrip() {
        let g = parse_graph(SAMPLE).unwrap();
        let text = write_graph(&g, "tiny");
        let g2 = parse_graph(&text).unwrap();
        assert_eq!(g.nodes.len(), g2.nodes.len());
        assert_eq!(g.edges.len(), g2.edges.len());
        assert_eq!(g2.nodes[0].label, "Paris");
        assert!((g2.nodes[1].lon.unwrap() - (-0.1278)).abs() < 1e-9);
    }

    #[test]
    fn rejects_missing_graph() {
        assert!(parse_graph("Creator \"x\"").is_err());
    }

    #[test]
    fn rejects_node_without_id() {
        let bad = "graph [ node [ label \"x\" ] ]";
        assert!(parse_graph(bad).is_err());
    }

    #[test]
    fn tolerates_comments_and_extras() {
        let src = "# hi\ngraph [ directed 0 node [ id 0 ] node [ id 1 ] edge [ source 0 target 1 weight 3 ] ]";
        let g = parse_graph(src).unwrap();
        assert_eq!(g.nodes.len(), 2);
        assert_eq!(g.edges.len(), 1);
    }
}
